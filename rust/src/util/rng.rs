//! Deterministic PRNG substrate (no `rand` crate in the offline registry).
//!
//! `SplitMix64` seeds `Xoshiro256StarStar`, the generator used everywhere a
//! reproducible stream is needed (graph generation, sampling, pruning,
//! client-local randomness). Every component derives its own stream from a
//! `(seed, stream-id)` pair so runs are bit-reproducible regardless of
//! thread scheduling.

/// SplitMix64: used to expand a single u64 seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 2^256-period PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a `(seed, stream)` pair; distinct streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // xoshiro must not be seeded with all zeros.
        if s.iter().all(|&x| x == 0) {
            s[0] = 1;
        }
        Self { s }
    }

    /// Raw generator state, for whole-session checkpointing
    /// (`coordinator/checkpoint.rs`).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from checkpointed [`state`](Rng::state). The
    /// all-zero guard is re-applied so a hand-built zero state cannot
    /// wedge the generator.
    pub fn from_state(mut s: [u64; 4]) -> Self {
        if s.iter().all(|&x| x == 0) {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
            // retry on the (rare) biased region
            let _ = x;
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached spare not kept: simple + fast
    /// enough for feature synthesis).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct items from `0..n` (floyd's algorithm for k << n,
    /// partial shuffle otherwise). Order is not specified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                all.swap(i, j);
            }
            all.truncate(k);
            return all;
        }
        // Floyd's: O(k) expected. For the small k of the sampler hot path
        // a linear-scan dedup beats a HashSet allocation per call (§Perf).
        if k <= 16 {
            let mut out: Vec<usize> = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let v = if out.contains(&t) { j } else { t };
                out.push(v);
            }
            return out;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Zipf-ish power-law integer in [0, n): inverse-CDF of p(i) ~ (i+1)^-a.
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        // Inverse transform on the continuous approximation.
        let u = self.f64();
        let exp = 1.0 - alpha;
        let x = if exp.abs() < 1e-9 {
            (n as f64).powf(u)
        } else {
            ((n as f64).powf(exp) * u + (1.0 - u)).powf(1.0 / exp)
        };
        (x as usize).min(n - 1)
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(42, 1);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7, 0);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_uniform_moments() {
        let mut r = Rng::new(3, 0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5, 0);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9, 0);
        for (n, k) in [(100, 5), (100, 80), (10, 10), (10, 12)] {
            let s = r.sample_indices(n, k);
            let mut set = std::collections::HashSet::new();
            for &v in &s {
                assert!(v < n);
                assert!(set.insert(v), "duplicate in sample");
            }
            assert_eq!(s.len(), k.min(n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11, 0);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn powerlaw_skews_low() {
        let mut r = Rng::new(13, 0);
        let n = 10_000;
        let low = (0..n).filter(|_| r.powerlaw(1000, 2.0) < 10).count();
        assert!(low > n / 2, "powerlaw not skewed: {low}");
    }
}
