//! Threading substrate (no tokio in the offline registry): a small
//! fixed-size thread pool for fire-and-forget jobs plus scoped data-parallel
//! helpers used by the graph algorithms and the multi-client session driver.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are executed FIFO; `wait_idle` blocks until
/// every submitted job has finished (used by the embedding push overlap).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            handles.push(
                thread::Builder::new()
                    .name(format!("optimes-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*inflight;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                if *n == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn pool thread"),
            );
        }
        Self {
            tx: Some(tx),
            handles,
            inflight,
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.inflight;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("pool send");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run `f(i, &items[i])` over all items on up to `threads` scoped workers,
/// collecting results in input order. Panics propagate.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let threads = threads.clamp(1, items.len().max(1));
    if threads == 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots_ptr = &slots_ptr;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY: each index i is claimed exactly once via the
                // atomic counter, so no two threads write the same slot,
                // and the scope outlives all writes.
                unsafe {
                    *slots_ptr.0.add(i) = Some(r);
                }
            });
        }
    });
    slots.into_iter().map(|r| r.expect("slot filled")).collect()
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Default worker count: physical parallelism minus one (leave a core for
/// the coordinator), at least 1.
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_idle_without_jobs() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn parallel_map_order_and_coverage() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 2);
        }
    }

    #[test]
    fn parallel_map_single_item() {
        let out = parallel_map(&[5usize], 8, |_, &x| x + 1);
        assert_eq!(out, vec![6]);
    }
}
