//! Threading substrate (no tokio in the offline registry): a small
//! fixed-size thread pool for fire-and-forget jobs, a scoped data-parallel
//! chunk API used by the tiled matmul kernels, and scoped map helpers used
//! by the graph algorithms and the multi-client session driver.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True on pool worker threads; lets [`ThreadPool::run_chunks`] detect
    /// nested dispatch (which would deadlock `wait_idle`) and run inline.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Fixed-size thread pool. Jobs are executed FIFO; `wait_idle` blocks until
/// every submitted job has finished (used by the embedding push overlap and
/// the kernel tile dispatch). Panicking jobs are caught so workers survive;
/// `run_chunks` re-raises panics from its own tiles on the calling thread.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            handles.push(
                thread::Builder::new()
                    .name(format!("optimes-pool-{i}"))
                    .spawn(move || {
                        IN_POOL_WORKER.with(|c| c.set(true));
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            match job {
                                Ok(job) => {
                                    let r = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(job),
                                    );
                                    if r.is_err() {
                                        crate::log!(
                                            Warn,
                                            "optimes-pool: job panicked (worker kept alive)"
                                        );
                                    }
                                    let (lock, cv) = &*inflight;
                                    let mut n = lock.lock().unwrap();
                                    *n -= 1;
                                    if *n == 0 {
                                        cv.notify_all();
                                    }
                                }
                                Err(_) => break, // channel closed
                            }
                        }
                    })
                    .expect("spawn pool thread"),
            );
        }
        Self {
            tx: Some(tx),
            handles,
            inflight,
        }
    }

    /// Worker count (used by callers to size chunks).
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.inflight;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("pool send");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Run `f(start, end)` over disjoint `chunk`-sized ranges of `0..n` on
    /// the pool, blocking until every range has been processed. Ranges are
    /// disjoint, so `f` may write through raw pointers into per-range slices
    /// of a shared output buffer (the kernel tile pattern).
    ///
    /// Completion is tracked by a per-dispatch latch (not the pool-wide
    /// inflight count), so concurrent `run_chunks` callers on the shared
    /// pool never wait on each other's tiles. Runs inline when the work is
    /// a single chunk or when called from a pool worker thread (nested
    /// dispatch would starve the latch).
    pub fn run_chunks<F: Fn(usize, usize) + Sync>(&self, n: usize, chunk: usize, f: F) {
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        if n <= chunk || IN_POOL_WORKER.with(|c| c.get()) {
            f(0, n);
            return;
        }
        let n_chunks = n.div_ceil(chunk);
        // Per-dispatch state: remaining-tile latch + panic flag, so callers
        // neither convoy on nor observe failures of other dispatches.
        let latch = (Mutex::new(n_chunks), std::sync::Condvar::new());
        let panicked = AtomicBool::new(false);
        let f_ref: &(dyn Fn(usize, usize) + Sync) = &f;
        // SAFETY: the latch wait below blocks until every job dispatched
        // here has finished, so no 'static borrow outlives its referent.
        let f_static = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize, usize) + Sync),
                &'static (dyn Fn(usize, usize) + Sync),
            >(f_ref)
        };
        let p_static =
            unsafe { std::mem::transmute::<&AtomicBool, &'static AtomicBool>(&panicked) };
        let l_static = unsafe {
            std::mem::transmute::<
                &(Mutex<usize>, std::sync::Condvar),
                &'static (Mutex<usize>, std::sync::Condvar),
            >(&latch)
        };
        let mut s = 0;
        while s < n {
            let e = (s + chunk).min(n);
            self.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f_static(s, e)));
                if r.is_err() {
                    p_static.store(true, Ordering::SeqCst);
                }
                let mut left = l_static.0.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    l_static.1.notify_all();
                }
            });
            s = e;
        }
        let mut left = latch.0.lock().unwrap();
        while *left > 0 {
            left = latch.1.wait(left).unwrap();
        }
        drop(left);
        if panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool job panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Process-wide shared pool for data-parallel kernel tiles, sized to
/// [`default_threads`]. Lazily created on first use.
pub fn global() -> &'static ThreadPool {
    static GLOBAL_POOL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL_POOL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// Run `f(i, &items[i])` over all items on up to `threads` scoped workers,
/// collecting results in input order. Panics propagate.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let threads = threads.clamp(1, items.len().max(1));
    if threads == 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots_ptr = &slots_ptr;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY: each index i is claimed exactly once via the
                // atomic counter, so no two threads write the same slot,
                // and the scope outlives all writes.
                unsafe {
                    *slots_ptr.0.add(i) = Some(r);
                }
            });
        }
    });
    slots.into_iter().map(|r| r.expect("slot filled")).collect()
}

/// Raw pointer wrapper that may cross a thread dispatch. Safe only when
/// the dispatch writes disjoint regions and the referent outlives every
/// job (the `parallel_map` slot pattern and the kernel tile pattern).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Default worker count: physical parallelism minus one (leave a core for
/// the coordinator), at least 1.
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_idle_without_jobs() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn run_chunks_covers_every_index_disjointly() {
        let pool = ThreadPool::new(4);
        let n = 1003; // deliberately not a multiple of the chunk size
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run_chunks(n, 64, |s, e| {
            assert!(s < e && e <= n);
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn run_chunks_single_chunk_runs_inline() {
        let pool = ThreadPool::new(2);
        // a single chunk must run on the calling thread, not a worker
        let tid = std::sync::Mutex::new(None);
        pool.run_chunks(10, 64, |s, e| {
            assert_eq!((s, e), (0, 10));
            *tid.lock().unwrap() = Some(thread::current().id());
        });
        assert_eq!(tid.into_inner().unwrap(), Some(thread::current().id()));
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.wait_idle();
        // workers must still be alive and processing
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    #[should_panic(expected = "ThreadPool job panicked")]
    fn run_chunks_propagates_job_panics() {
        let pool = ThreadPool::new(2);
        pool.run_chunks(100, 10, |s, _| {
            if s == 50 {
                panic!("tile failed");
            }
        });
    }

    #[test]
    fn global_pool_is_shared_and_usable() {
        let a = global() as *const ThreadPool;
        let b = global() as *const ThreadPool;
        assert_eq!(a, b);
        let total = AtomicUsize::new(0);
        global().run_chunks(256, 16, |s, e| {
            total.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 256);
    }

    #[test]
    fn parallel_map_order_and_coverage() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 2);
        }
    }

    #[test]
    fn parallel_map_single_item() {
        let out = parallel_map(&[5usize], 8, |_, &x| x + 1);
        assert_eq!(out, vec![6]);
    }
}
