//! Hand-rolled substrates: the offline crate registry only carries the
//! `xla` crate's dependency closure, so the PRNG, JSON, CLI, threading,
//! statistics, and property-testing layers are implemented here from
//! scratch (see DESIGN.md §3, substitutions table).

pub mod cli;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Wall-clock stopwatch used by phase metrics.
#[derive(Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Format seconds human-readably for log lines and tables.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}m", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(0.0000005).ends_with("us"));
        assert!(fmt_secs(0.005).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
        assert!(fmt_secs(300.0).ends_with('m'));
    }
}
