//! CLI argument substrate (no clap in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! typed accessors with defaults. Used by `main.rs` and the examples.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixes_positional_options_flags() {
        // NB: a bare `--flag` followed by a non-dash token consumes it as a
        // value, so trailing flags must come last (documented behaviour).
        let a = parse("run extra --dataset reddit-s --rounds=12 --verbose");
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get("dataset"), Some("reddit-s"));
        assert_eq!(a.usize_or("rounds", 0), 12);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("n", 7), 7);
        assert!((a.f64_or("lr", 0.001) - 0.001).abs() < 1e-12);
        assert_eq!(a.str_or("mode", "fast"), "fast");
    }

    #[test]
    fn list_option() {
        let a = parse("--strategies D,E,OP");
        assert_eq!(
            a.list("strategies").unwrap(),
            vec!["D".to_string(), "E".to_string(), "OP".to_string()]
        );
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
