//! Statistics substrate for metrics and the bench harness: summaries,
//! percentiles, online moments, moving averages, and the least-squares fit
//! used to reproduce the paper's Fig 12c (nodes/RPC vs service time, R²).

/// Five-number-ish summary of a sample.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p95: f64,
    pub max: f64,
}

/// Percentile by linear interpolation on the sorted sample (q in [0,1]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p25: percentile(&sorted, 0.25),
        median: percentile(&sorted, 0.5),
        p75: percentile(&sorted, 0.75),
        p95: percentile(&sorted, 0.95),
        max: sorted[n - 1],
    }
}

pub fn median(xs: &[f64]) -> f64 {
    summarize(xs).median
}

/// Ordinary least squares y = a + b·x with R².
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinFit {
    pub intercept: f64,
    pub slope: f64,
    pub r2: f64,
}

pub fn linfit(xs: &[f64], ys: &[f64]) -> Option<LinFit> {
    let n = xs.len();
    if n < 2 || n != ys.len() {
        return None;
    }
    let nf = n as f64;
    let mx = xs.iter().sum::<f64>() / nf;
    let my = ys.iter().sum::<f64>() / nf;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 {
        return None;
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    Some(LinFit {
        intercept,
        slope,
        r2,
    })
}

/// Trailing moving average with window `w` (the paper smooths accuracy
/// convergence over 5 rounds).
pub fn moving_average(xs: &[f64], w: usize) -> Vec<f64> {
    let w = w.max(1);
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0;
    for i in 0..xs.len() {
        acc += xs[i];
        if i >= w {
            acc -= xs[i - w];
        }
        let len = (i + 1).min(w) as f64;
        out.push(acc / len);
    }
    out
}

/// Welford online mean/variance, used by long-running metric streams.
#[derive(Clone, Copy, Debug, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(median(&[2.0, 1.0]), 1.5);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.5), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn linfit_exact_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let f = linfit(&xs, &ys).unwrap();
        assert!((f.slope - 2.0).abs() < 1e-9);
        assert!((f.intercept - 3.0).abs() < 1e-9);
        assert!((f.r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_noise_r2_below_one() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let f = linfit(&xs, &ys).unwrap();
        assert!(f.r2 < 1.0 && f.r2 > 0.9);
    }

    #[test]
    fn moving_average_window() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ma = moving_average(&xs, 2);
        assert_eq!(ma, vec![1.0, 1.5, 2.5, 3.5]);
        assert_eq!(moving_average(&xs, 1), xs.to_vec());
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let s = summarize(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-12);
    }
}
