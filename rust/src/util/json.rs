//! Minimal JSON substrate (the offline registry has no serde/serde_json).
//!
//! Covers the full JSON grammar we exchange with the Python build path
//! (`artifacts/manifest.json`) and the report files the benches write:
//! objects, arrays, strings (with escapes), numbers, bools, null. Object
//! key order is preserved (insertion order) so emitted reports are stable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved via the side vector; map gives O(log n) lookup.
    Obj(JsonObj),
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Json>) -> &mut Self {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value.into());
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

impl Json {
    // ---- typed accessors ----
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn at(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    // ---- serialization ----
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        v.write(out, Some(ind + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    if !a.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind * 2));
                    }
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, k) in o.keys().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        write_escaped(out, k);
                        out.push_str(": ");
                        o.get(k).unwrap().write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        o.get(k).unwrap().write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    if !o.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind * 2));
                    }
                }
                out.push('}');
            }
        }
    }

    // ---- parsing ----
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 2..self.pos + 6],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                code
                            };
                            out.push(
                                char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.set(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---- From conversions for ergonomic report building ----
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<JsonObj> for Json {
    fn from(v: JsonObj) -> Self {
        Json::Obj(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"version":1,"entrypoints":[{"name":"gc_train","shape":[32,5],"ok":true,"x":null,"f":-1.5e-3}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.at("version").as_usize(), Some(1));
        let ep = v.at("entrypoints").idx(0);
        assert_eq!(ep.at("name").as_str(), Some("gc_train"));
        assert_eq!(ep.at("shape").idx(1).as_usize(), Some(5));
        assert_eq!(ep.at("ok").as_bool(), Some(true));
        assert_eq!(*ep.at("x"), Json::Null);
        assert!((ep.at("f").as_f64().unwrap() + 0.0015).abs() < 1e-12);
        // re-parse what we serialize
        let again = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, again);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA\u{e9}"));
        let s = Json::Str("x\n\"y\"\t".into()).to_string_compact();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("x\n\"y\"\t"));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "tru", "\"abc", "1 2", "{\"a\" 1}", ""] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn object_builder_preserves_order() {
        let mut o = JsonObj::new();
        o.set("z", 1usize).set("a", 2usize).set("m", "x");
        let keys: Vec<_> = o.keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert_eq!(v.at("version").as_usize(), Some(1));
            assert!(!v.at("entrypoints").as_arr().unwrap().is_empty());
        }
    }
}
