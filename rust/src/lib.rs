//! # OptimES
//!
//! A Rust + JAX + Pallas reproduction of *OptimES: Optimizing Federated
//! Learning Using Remote Embeddings for Graph Neural Networks* (Naman &
//! Simmhan, 2025).
//!
//! Three layers (see DESIGN.md):
//! * **L3 (this crate)** — the federated coordinator: aggregation server,
//!   embedding server, clients, pruning/overlap/prefetch strategies.
//! * **L2 (python/compile/model.py)** — GraphConv/SAGEConv forward +
//!   backward + Adam, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels)** — fused Pallas aggregation kernels
//!   inside the same artifacts.
//!
//! The crate is organized bottom-up: [`util`] (hand-rolled substrates),
//! [`obs`] (span tracing, metrics registry, leveled logging),
//! [`storage`] (the out-of-core graph plane: on-disk CSR + mmap seam),
//! [`graph`] (data + sampling), [`runtime`] (PJRT execution engines), and
//! [`coordinator`] (the paper's system contribution).

pub mod graph;
pub mod obs;
pub mod storage;
pub mod util;

pub mod coordinator;
pub mod harness;
pub mod runtime;
pub mod wire;
