//! Bench target regenerating: Fig 12 — pull-phase analysis
//! (cargo bench --bench fig12_pull_analysis; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig12().expect("fig12_pull_analysis");
    println!("\n[fig12_pull_analysis] done in {:.1}s", t0.elapsed().as_secs_f64());
}
