//! Multi-tenant load generator for the embedding daemon: hammers one
//! sharded+replicated `EmbServerDaemon` with hundreds (or, without
//! `--quick`, thousands) of short-lived simulated clients spread across
//! several tenant namespaces, recording p50/p99/p999 push/pull wire
//! latencies plus admission-control rejection counts — the latency
//! number behind the north-star's "heavy traffic" claim (EXPERIMENTS.md
//! §Load testing, DESIGN.md §15).
//!
//! Three phases:
//! 1. **churn** — a bounded worker pool drains the client queue; each
//!    client connects (with a TENANT handshake), does a few push/pull
//!    rounds, and disconnects. This is exactly the connect/disconnect
//!    churn that used to leak handler `JoinHandle`s.
//! 2. **saturation probe** — hold `max_conns` admitted connections, then
//!    probe extras and require every one to get the loud `BUSY` verdict.
//! 3. **drain** — drop everything and require the daemon's live-conn and
//!    handler-thread gauges to hit zero (the zero-leak acceptance gate).
//!
//! Merges a `loadgen` section into the repo-root `BENCH_micro.json`.
//!
//! Flags: `--quick` (CI scale), `--clients N`, `--tenants N`,
//! `--shards N`, `--replicas R`, `--workers N`, `--ops N`, `--batch N`,
//! `--max-conns N`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use optimes::coordinator::{
    DaemonConfig, EmbServerDaemon, EmbeddingServer, EmbeddingStore, NetConfig, RemoteEmbClient,
    ShardedStore,
};
use optimes::harness;
use optimes::obs::Histogram;
use optimes::util::cli::Args;
use optimes::util::json::JsonObj;
use optimes::wire::CodecKind;

const N_LAYERS: usize = 2;
const HIDDEN: usize = 16;

struct Scale {
    quick: bool,
    clients: usize,
    tenants: usize,
    shards: usize,
    replicas: usize,
    workers: usize,
    ops_per_client: usize,
    batch: usize,
    max_conns: usize,
}

impl Scale {
    fn from_args(args: &Args) -> Scale {
        let quick = args.flag("quick");
        Scale {
            quick,
            clients: args.usize_or("clients", if quick { 200 } else { 2000 }),
            tenants: args.usize_or("tenants", if quick { 2 } else { 4 }).max(1),
            shards: args.usize_or("shards", 4),
            replicas: args.usize_or("replicas", 1),
            workers: args.usize_or("workers", if quick { 16 } else { 32 }).max(1),
            ops_per_client: args.usize_or("ops", if quick { 2 } else { 4 }),
            batch: args.usize_or("batch", 32),
            max_conns: args.usize_or("max-conns", 64),
        }
    }
}

fn rows(nodes: &[u32], salt: f32) -> Vec<f32> {
    nodes
        .iter()
        .flat_map(|&n| (0..HIDDEN).map(move |j| n as f32 * 0.01 + j as f32 * 0.25 + salt))
        .collect()
}

fn is_busy(e: &anyhow::Error) -> bool {
    format!("{e:#}").contains("BUSY")
}

/// Poll daemon stats until `pred` holds (panics with the last stats
/// snapshot after `secs` seconds).
fn await_daemon(
    d: &EmbServerDaemon,
    what: &str,
    secs: u64,
    pred: impl Fn(&optimes::coordinator::DaemonStats) -> bool,
) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(secs);
    loop {
        let s = d.stats();
        if pred(&s) {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "daemon never reached {what}: {s:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}

fn main() {
    let args = Args::parse_env();
    let s = Scale::from_args(&args);
    assert!(
        s.shards > s.replicas,
        "need shards > replicas for a replicated store"
    );

    let backends: Vec<Arc<dyn EmbeddingStore>> = (0..s.shards)
        .map(|_| {
            Arc::new(EmbeddingServer::new(N_LAYERS, HIDDEN, NetConfig::default()))
                as Arc<dyn EmbeddingStore>
        })
        .collect();
    let store: Arc<dyn EmbeddingStore> =
        Arc::new(ShardedStore::replicated(backends, s.replicas).expect("replicated store"));
    let daemon = EmbServerDaemon::start_with(
        Arc::clone(&store),
        "127.0.0.1:0",
        DaemonConfig {
            max_conns: s.max_conns,
            max_inflight: 0,
        },
    )
    .expect("daemon start");
    let addr = daemon.addr.to_string();
    println!(
        "loadgen: {} clients x {} ops over {} tenants -> {} ({} shards, {} replica(s), \
         max-conns {}, {} workers)",
        s.clients,
        s.ops_per_client,
        s.tenants,
        addr,
        s.shards,
        s.replicas,
        s.max_conns,
        s.workers
    );

    // phase 1: connect/use/disconnect churn through a bounded worker
    // pool. Latencies go into the shared obs::Histogram (the same
    // log-bucketed type the daemon scrapes over op=6): each worker
    // records into a private histogram and merges it in at exit.
    let t0 = std::time::Instant::now();
    let next = AtomicUsize::new(0);
    let push_hist = Histogram::new();
    let pull_hist = Histogram::new();
    let busy_rejections = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..s.workers.min(s.clients) {
            scope.spawn(|| {
                let my_push = Histogram::new();
                let my_pull = Histogram::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= s.clients {
                        break;
                    }
                    let tenant = format!("tenant-{}", i % s.tenants);
                    let mut c = match RemoteEmbClient::connect_opts(
                        addr.as_str(),
                        N_LAYERS,
                        HIDDEN,
                        &CodecKind::Raw,
                        Some(&tenant),
                    ) {
                        Ok(c) => c,
                        Err(e) if is_busy(&e) => {
                            busy_rejections.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        Err(e) => panic!("client {i} connect: {e:#}"),
                    };
                    let nodes: Vec<u32> =
                        ((i * s.batch) as u32..(i * s.batch + s.batch) as u32).collect();
                    for op in 0..s.ops_per_client {
                        let layer = rows(&nodes, op as f32);
                        let per_layer = vec![layer; N_LAYERS];
                        let w0 = std::time::Instant::now();
                        match c.push(&nodes, &per_layer) {
                            Ok(_) => my_push.record_secs(w0.elapsed().as_secs_f64()),
                            Err(e) if is_busy(&e) => {
                                busy_rejections.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(e) => panic!("client {i} push: {e:#}"),
                        }
                        let w0 = std::time::Instant::now();
                        match c.pull(&nodes) {
                            Ok((got, _)) => {
                                my_pull.record_secs(w0.elapsed().as_secs_f64());
                                assert_eq!(got[0], per_layer[0], "client {i} read own write");
                            }
                            Err(e) if is_busy(&e) => {
                                busy_rejections.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(e) => panic!("client {i} pull: {e:#}"),
                        }
                    }
                }
                push_hist.merge_from(&my_push);
                pull_hist.merge_from(&my_pull);
            });
        }
    });
    let churn_secs = t0.elapsed().as_secs_f64();

    // phase 2: saturate the connection cap, then require every extra
    // connection to get the loud BUSY verdict (not a hang, not an RST)
    await_daemon(&daemon, "post-churn drain", 10, |st| st.live_conns == 0);
    let mut held = Vec::new();
    for i in 0..s.max_conns {
        let mut c = RemoteEmbClient::connect(addr.as_str(), N_LAYERS, HIDDEN)
            .unwrap_or_else(|e| panic!("held conn {i} connect: {e:#}"));
        // stats round-trip proves the connection is admitted and served
        c.stats().unwrap_or_else(|e| panic!("held conn {i} not admitted: {e:#}"));
        held.push(c);
    }
    let probe_attempts = 32usize;
    let mut probe_rejected = 0usize;
    for i in 0..probe_attempts {
        let mut c = RemoteEmbClient::connect(addr.as_str(), N_LAYERS, HIDDEN)
            .unwrap_or_else(|e| panic!("probe conn {i} connect: {e:#}"));
        match c.stats() {
            Err(e) if is_busy(&e) => probe_rejected += 1,
            Err(e) => panic!("probe conn {i}: expected BUSY, got {e:#}"),
            Ok(_) => panic!("probe conn {i} was admitted past the max-conns cap"),
        }
    }
    assert_eq!(
        probe_rejected, probe_attempts,
        "every over-cap probe must be rejected with BUSY"
    );
    drop(held);

    // phase 3: drain — the zero-leak gate (bounded handler threads)
    await_daemon(&daemon, "zero live conns + zero handler threads", 10, |st| {
        st.live_conns == 0 && st.handler_threads == 0
    });
    let dstats = daemon.stats();
    assert!(dstats.rejected_conns >= probe_attempts, "{dstats:?}");
    assert_eq!(dstats.tenants, s.tenants, "{dstats:?}");
    assert!(dstats.peak_conns <= s.max_conns, "{dstats:?}");

    let (push_p50, push_p99, push_p999) = (
        push_hist.quantile_ms(0.50),
        push_hist.quantile_ms(0.99),
        push_hist.quantile_ms(0.999),
    );
    let (pull_p50, pull_p99, pull_p999) = (
        pull_hist.quantile_ms(0.50),
        pull_hist.quantile_ms(0.99),
        pull_hist.quantile_ms(0.999),
    );
    println!(
        "churn: {} clients in {churn_secs:.2}s | push p50/p99/p999 {push_p50:.3}/{push_p99:.3}/\
         {push_p999:.3} ms | pull p50/p99/p999 {pull_p50:.3}/{pull_p99:.3}/{pull_p999:.3} ms",
        s.clients
    );
    println!(
        "admission: {} held, {}/{} probes rejected, daemon {:?}",
        s.max_conns, probe_rejected, probe_attempts, dstats
    );

    let mut push_obj = JsonObj::new();
    push_obj
        .set("ops", push_hist.count() as usize)
        .set("p50_ms", push_p50)
        .set("p99_ms", push_p99)
        .set("p999_ms", push_p999);
    let mut pull_obj = JsonObj::new();
    pull_obj
        .set("ops", pull_hist.count() as usize)
        .set("p50_ms", pull_p50)
        .set("p99_ms", pull_p99)
        .set("p999_ms", pull_p999);
    let mut out = JsonObj::new();
    out.set("quick", s.quick)
        .set("shards", s.shards)
        .set("replicas", s.replicas)
        .set("tenants", s.tenants)
        .set("clients", s.clients)
        .set("workers", s.workers.min(s.clients))
        .set("ops_per_client", s.ops_per_client)
        .set("batch", s.batch)
        .set("max_conns", s.max_conns)
        .set("churn_secs", churn_secs)
        .set("push", push_obj)
        .set("pull", pull_obj)
        .set("busy_rejections", busy_rejections.load(Ordering::Relaxed))
        .set("probe_attempts", probe_attempts)
        .set("probe_rejected", probe_rejected)
        .set("rejected_conns", dstats.rejected_conns)
        .set("rejected_requests", dstats.rejected_requests)
        .set("peak_conns", dstats.peak_conns)
        .set("total_conns", dstats.total_conns)
        .set("live_conns_at_end", dstats.live_conns)
        .set("handler_threads_at_end", dstats.handler_threads)
        .set("tenants_registered", dstats.tenants);
    harness::record_bench_section("loadgen", out);
    println!("recorded loadgen section into {}", harness::bench_json_path().display());

    daemon.shutdown();
}
