//! Bench target regenerating: Fig 7 — round-time breakdowns (GraphConv)
//! (cargo bench --bench fig7_round_breakdown; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig7(optimes::runtime::ModelKind::Gc, &["arxiv-s", "reddit-s", "products-s", "papers-s"]).expect("fig7_round_breakdown");
    println!("\n[fig7_round_breakdown] done in {:.1}s", t0.elapsed().as_secs_f64());
}
