//! End-to-end round-time harness: a 4-client, 3-round federated session
//! (the `federation_e2e` configuration) run twice at the same seed — once
//! with the tiled parallel kernels, once with the naive scalar oracle
//! forced — to record the wall-clock speedup and confirm the final
//! validation accuracy is unchanged (EXPERIMENTS.md §Perf). A second A/B
//! measures the async store pipeline: the same session with `pipeline`
//! on vs. off against a *throttled* store (the netsim cost model slept
//! out for real), recording the real-vs-virtual overlap
//! (`overlap_saved`, `push_wall`) and verifying accuracy parity.
//!
//! Merges `roundtime` + `pipeline` sections into the repo-root
//! `BENCH_micro.json`.

use std::sync::Arc;

use optimes::coordinator::{
    run_session, EmbeddingServer, EmbeddingStore, NetConfig, SessionBuilder, SessionConfig,
    SessionMetrics, Strategy, ThrottledStore,
};
use optimes::graph::datasets::tiny;
use optimes::harness;
use optimes::runtime::{kernels, ModelGeom, ModelKind, RefEngine, StepEngine};
use optimes::util::json::JsonObj;

const CLIENTS: usize = 4;
const ROUNDS: usize = 3;

/// Geometry sized so the layer-1 matmuls (~2.4M MACs at B=32, K=5,
/// hidden=64) cross the kernels' parallel-dispatch threshold — the timed
/// sessions exercise the full tiled + row-tile-parallel path, not just
/// the serial tiling. (feat/classes must match the `tiny` generator.)
fn engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: 64,
        classes: 4,
        batch: 32,
        fanout: 5,
        push_batch: 32,
    }))
}

fn cfg(rounds: usize) -> SessionConfig {
    SessionConfig {
        clients: CLIENTS,
        strategy: Strategy::o(),
        rounds,
        epochs: 3,
        epoch_batches: 6,
        eval_batches: 6,
        lr: 0.01,
        seed: 42,
        parallel_clients: false,
        ..Default::default()
    }
}

fn run_once(label: &str) -> (f64, SessionMetrics) {
    let g = tiny(42);
    let t0 = std::time::Instant::now();
    let m = run_session(&g, &cfg(ROUNDS), engine()).expect(label);
    let wall = t0.elapsed().as_secs_f64();
    let final_acc = m.rounds.last().map(|r| r.accuracy).unwrap_or(0.0);
    println!(
        "{label:<18} wall {wall:>8.3}s  ({:.3}s/round)  final acc {final_acc:.4}",
        wall / ROUNDS as f64
    );
    (wall, m)
}

/// Pipeline A/B config: sequential clients for bit-parity, OPP so both
/// the prefetch and the overlapped-push paths are exercised.
fn pipeline_cfg(pipeline: bool) -> SessionConfig {
    SessionConfig {
        clients: CLIENTS,
        strategy: Strategy::opp(),
        rounds: ROUNDS,
        epochs: 3,
        epoch_batches: 6,
        eval_batches: 6,
        lr: 0.01,
        seed: 42,
        parallel_clients: false,
        pipeline,
        ..Default::default()
    }
}

fn run_pipeline(label: &str, pipeline: bool) -> (f64, SessionMetrics) {
    let g = tiny(42);
    // throttle the in-process store so its netsim virtual time becomes
    // real wall time: the on/off wall delta is then the pipeline's true
    // overlap win, deterministic and network-free
    let net = NetConfig {
        latency: 2e-3,
        ..NetConfig::default()
    };
    let store: Arc<dyn EmbeddingStore> =
        Arc::new(ThrottledStore::new(Arc::new(EmbeddingServer::new(2, 64, net))));
    let t0 = std::time::Instant::now();
    let m = SessionBuilder::new(pipeline_cfg(pipeline))
        .store(store)
        .build(&g, engine())
        .expect(label)
        .run()
        .expect(label);
    let wall = t0.elapsed().as_secs_f64();
    let ov = m.overlap_stats();
    println!(
        "{label:<18} wall {wall:>8.3}s  push_wall {:.3}s  overlap_saved {:.3}s  queue<= {}",
        ov.push_wall, ov.overlap_saved, ov.queue_peak
    );
    (wall, m)
}

fn main() {
    println!("== bench_roundtime ({CLIENTS} clients, {ROUNDS} rounds, seed 42) ==");
    // Untimed warm-up round: spawns the kernel thread pool, faults in the
    // dataset/allocator working set, so neither timed run pays one-time
    // process start-up costs.
    kernels::set_force_naive(false);
    let g = tiny(42);
    run_session(&g, &cfg(1), engine()).expect("warm-up");
    let (tiled_wall, tiled) = run_once("kernels: tiled");
    kernels::set_force_naive(true);
    let (naive_wall, naive) = run_once("kernels: naive");
    kernels::set_force_naive(false);

    let acc_t = tiled.rounds.last().map(|r| r.accuracy).unwrap_or(0.0);
    let acc_n = naive.rounds.last().map(|r| r.accuracy).unwrap_or(0.0);
    let acc_delta = (acc_t - acc_n).abs();
    let speedup = naive_wall / tiled_wall.max(1e-12);
    println!(
        "speedup {speedup:.2}x  |final acc delta| {acc_delta:.2e} (target <= 1e-4)"
    );
    if acc_delta > 1e-4 {
        eprintln!("WARNING: accuracy drifted beyond 1e-4 between kernel paths");
    }

    let mut o = JsonObj::new();
    o.set("clients", CLIENTS);
    o.set("rounds", ROUNDS);
    o.set("tiled_wall_s", tiled_wall);
    o.set("naive_wall_s", naive_wall);
    o.set("tiled_s_per_round", tiled_wall / ROUNDS as f64);
    o.set("naive_s_per_round", naive_wall / ROUNDS as f64);
    o.set("wall_speedup", speedup);
    o.set("final_acc_tiled", acc_t);
    o.set("final_acc_naive", acc_n);
    o.set("final_acc_delta", acc_delta);
    o.set("train_phase_tiled_s", tiled.median_phases().train);
    o.set("train_phase_naive_s", naive.median_phases().train);
    harness::record_bench_section("roundtime", o);

    // ---- async-pipeline A/B: real overlap under a throttled store -------
    println!("\n== pipeline A/B ({CLIENTS} clients, {ROUNDS} rounds, throttled store, OPP) ==");
    let (on_wall, on) = run_pipeline("pipeline: on", true);
    let (off_wall, off) = run_pipeline("pipeline: off", false);
    let ov = on.overlap_stats();
    let parity = on.accuracies() == off.accuracies();
    let pipe_speedup = off_wall / on_wall.max(1e-12);
    println!(
        "pipeline speedup {pipe_speedup:.2}x  overlap_saved {:.3}s (real)  \
         virtual push_hidden {:.3}s  accuracy parity {parity}",
        ov.overlap_saved,
        on.rounds.iter().map(|r| r.mean_phases.push_hidden).sum::<f64>(),
    );
    if !parity {
        eprintln!("WARNING: pipeline on/off accuracy curves diverged");
    }

    let mut p = JsonObj::new();
    p.set("pipeline_on_wall_s", on_wall);
    p.set("pipeline_off_wall_s", off_wall);
    p.set("pipeline_speedup", pipe_speedup);
    p.set("overlap", ov.to_json());
    p.set("accuracy_parity", parity);
    harness::record_bench_section("pipeline", p);
    println!("[bench_roundtime] recorded to {}", harness::bench_json_path().display());
}
