//! Bench target regenerating: Fig 11 — scoring-strategy ablation
//! (cargo bench --bench fig11_scoring; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig11().expect("fig11_scoring");
    println!("\n[fig11_scoring] done in {:.1}s", t0.elapsed().as_secs_f64());
}
