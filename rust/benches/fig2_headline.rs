//! Bench target regenerating Fig 2a (remote-vertex fractions + embeddings
//! maintained) and Fig 2b (headline time-to-accuracy on Products).
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig2a().expect("fig2a");
    figures::fig2b().expect("fig2b");
    println!("\n[fig2_headline] done in {:.1}s", t0.elapsed().as_secs_f64());
}
