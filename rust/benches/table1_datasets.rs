//! Bench target regenerating: Table 1 — dataset statistics
//! (cargo bench --bench table1_datasets; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::table1().expect("table1_datasets");
    println!("\n[table1_datasets] done in {:.1}s", t0.elapsed().as_secs_f64());
}
