//! Bench target regenerating: Fig 13 — client scaling
//! (cargo bench --bench fig13_scaling; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig13().expect("fig13_scaling");
    println!("\n[fig13_scaling] done in {:.1}s", t0.elapsed().as_secs_f64());
}
