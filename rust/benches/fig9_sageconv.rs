//! Bench target regenerating: Fig 9 — SAGEConv TTA/accuracy + breakdowns
//! (cargo bench --bench fig9_sageconv; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig9().expect("fig9_sageconv");
    println!("\n[fig9_sageconv] done in {:.1}s", t0.elapsed().as_secs_f64());
}
