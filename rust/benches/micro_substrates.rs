//! Micro-benchmarks of the L3 hot-path substrates (hand-rolled harness —
//! the offline registry carries no criterion). Reports ns/op with simple
//! repetition + median-of-runs, which is what the §Perf iteration log in
//! EXPERIMENTS.md tracks.

use std::sync::Arc;

use optimes::coordinator::trainer::assemble_batch;
use optimes::coordinator::{EmbeddingServer, NetConfig};
use optimes::graph::datasets;
use optimes::graph::partition::{hash_partition, metis_lite};
use optimes::graph::sampler::{static_adj, Sampler};
use optimes::graph::scoring;
use optimes::graph::subgraph::{build_all, Prune};
use optimes::harness;
use optimes::runtime::{ModelState, StepEngine};

/// Time `f` over `iters` iterations, repeated 5 times; report the median.
fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    let mut runs = Vec::new();
    for _ in 0..5 {
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            f();
        }
        runs.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = runs[2];
    let unit = if med < 1e-6 {
        format!("{:.0} ns/op", med * 1e9)
    } else if med < 1e-3 {
        format!("{:.2} us/op", med * 1e6)
    } else if med < 1.0 {
        format!("{:.3} ms/op", med * 1e3)
    } else {
        format!("{:.3} s/op", med)
    };
    println!("{name:<44} {unit:>16}   ({iters} iters x 5 runs)");
}

fn main() {
    let t0 = std::time::Instant::now();
    println!("== micro_substrates ==");
    let (p, g) = harness::load_dataset("reddit-s").expect("dataset");

    bench("graph: generate reddit-s (scaled)", 1, || {
        let _ = datasets::load("reddit-s", harness::dataset_scale() * 2).unwrap();
    });

    let part = metis_lite(&g, p.default_clients, 42);
    bench("partition: metis_lite k=4", 1, || {
        let _ = metis_lite(&g, 4, 43);
    });
    bench("partition: hash k=4", 1, || {
        let _ = hash_partition(&g, 4, 43);
    });

    let subs = build_all(&g, &part, &Prune::None, 42);
    bench("subgraph: build_all (expansion, no prune)", 1, || {
        let _ = build_all(&g, &part, &Prune::None, 43);
    });
    bench("subgraph: build_all (P4 retention)", 1, || {
        let _ = build_all(&g, &part, &Prune::Retention(4), 43);
    });

    let sub = subs.iter().max_by_key(|s| s.n_remote()).unwrap();
    bench("scoring: frequency (768 sources)", 1, || {
        let _ = scoring::frequency_scores(sub, 3, 768, 7);
    });

    // sampling + assembly hot path (the per-minibatch L3 work)
    let engine = harness::make_engine(optimes::runtime::ModelKind::Gc, 5).expect("engine");
    let geom = *engine.geom();
    let dims = geom.dims();
    let mut sampler = Sampler::new(dims, 1, 0);
    let targets: Vec<u32> = sub.train_local.iter().copied().take(dims.batch).collect();
    bench("sampler: sample_batch (B=32, K=5, L=3)", 100, || {
        let _ = sampler.sample_batch(sub, &targets);
    });

    let adj = static_adj(&dims, dims.batch, dims.layers);
    let blocks = sampler.sample_batch(sub, &targets);
    let cache = optimes::coordinator::EmbCache::new(geom.layers - 1, geom.hidden, sub.n_remote());
    bench("trainer: assemble_batch (B=32)", 100, || {
        let _ = assemble_batch(&blocks, sub, &cache, &g, &adj, true);
    });

    // embedding server batched RPCs
    let server = EmbeddingServer::new(2, geom.hidden, NetConfig::default());
    let nodes: Vec<u32> = (0..10_000u32).collect();
    let rows = vec![0.5f32; nodes.len() * geom.hidden];
    bench("kv: push 10k x 2 layers", 10, || {
        let _ = server.push(&nodes, &[rows.clone(), rows.clone()]);
    });
    bench("kv: pull 10k x 2 layers", 10, || {
        let _ = server.pull(&nodes, false);
    });

    // engine step latency (the L1/L2 hot path through PJRT or Ref)
    let batch = assemble_batch(&blocks, sub, &cache, &g, &adj, true);
    let mut state = ModelState::init(&geom, 3);
    let eng: &Arc<dyn StepEngine> = &engine;
    bench(
        &format!("engine({}): train_step B=32", harness::engine_kind()),
        20,
        || {
            let _ = eng.train_step(&mut state, &batch, 0.01).unwrap();
        },
    );
    bench(
        &format!("engine({}): evaluate B=32", harness::engine_kind()),
        20,
        || {
            let _ = eng.evaluate(&state, &batch).unwrap();
        },
    );

    println!("\n[micro_substrates] done in {:.1}s", t0.elapsed().as_secs_f64());
}
