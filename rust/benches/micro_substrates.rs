//! Micro-benchmarks of the L3 hot-path substrates (hand-rolled harness —
//! the offline registry carries no criterion). Reports ns/op with simple
//! repetition + median-of-runs, prints one machine-readable JSON line per
//! substrate, and merges the full result set into the repo-root
//! `BENCH_micro.json` (the §Perf iteration log in EXPERIMENTS.md).
//!
//! `-- --quick` runs every substrate once with minimal repetition — a CI
//! smoke that proves the bench paths execute without recording numbers.

use std::sync::Arc;

use optimes::coordinator::net_transport::{EmbServerDaemon, TcpEmbeddingStore};
use optimes::coordinator::trainer::{assemble_batch, BatchScratch};
use optimes::coordinator::{EmbeddingServer, EmbeddingStore, NetConfig};
use optimes::graph::datasets;
use optimes::graph::partition::{hash_partition, metis_lite};
use optimes::graph::sampler::{static_adj, Sampler};
use optimes::graph::scoring;
use optimes::graph::subgraph::{build_all, Prune};
use optimes::harness;
use optimes::runtime::{kernels, ModelState, StepEngine};
use optimes::storage::{load_graph_file, write_graph_file, GraphBackend};
use optimes::util::json::{Json, JsonObj};
use optimes::util::rng::Rng;

/// Collected (name, seconds-per-op) results for the JSON section.
struct Results {
    entries: Vec<(String, f64)>,
    /// Smoke mode: 1 iteration x 2 runs per substrate, nothing recorded.
    quick: bool,
}

impl Results {
    /// Time `f` over `iters` iterations, repeated 5 times; report and
    /// record the median. Prints a human line plus a JSON line.
    fn bench(&mut self, name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
        let (iters, reps) = if self.quick { (1, 2) } else { (iters, 5) };
        let mut runs = Vec::new();
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                f();
            }
            runs.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = runs[reps / 2];
        let unit = if med < 1e-6 {
            format!("{:.0} ns/op", med * 1e9)
        } else if med < 1e-3 {
            format!("{:.2} us/op", med * 1e6)
        } else if med < 1.0 {
            format!("{:.3} ms/op", med * 1e3)
        } else {
            format!("{:.3} s/op", med)
        };
        println!("{name:<44} {unit:>16}   ({iters} iters x {reps} runs)");
        println!(
            "{{\"substrate\":{:?},\"ns_per_op\":{:.1},\"iters\":{iters}}}",
            name,
            med * 1e9
        );
        self.entries.push((name.to_string(), med));
        med
    }

    fn to_json(&self, extra: &[(&str, f64)]) -> JsonObj {
        let mut o = JsonObj::new();
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|(name, secs)| {
                let mut e = JsonObj::new();
                e.set("substrate", name.as_str());
                e.set("ns_per_op", secs * 1e9);
                Json::Obj(e)
            })
            .collect();
        o.set("entries", Json::Arr(entries));
        for (k, v) in extra {
            o.set(*k, *v);
        }
        o
    }
}

fn main() {
    let t0 = std::time::Instant::now();
    let quick = std::env::args().any(|a| a == "--quick");
    println!(
        "== micro_substrates{} ==",
        if quick { " (--quick smoke)" } else { "" }
    );
    let mut res = Results {
        entries: Vec::new(),
        quick,
    };
    let (p, g) = harness::load_dataset("reddit-s").expect("dataset");

    res.bench("graph: generate reddit-s (scaled)", 1, || {
        let _ = datasets::load("reddit-s", harness::dataset_scale() * 2).unwrap();
    });

    let part = metis_lite(&g, p.default_clients, 42);
    res.bench("partition: metis_lite k=4", 1, || {
        let _ = metis_lite(&g, 4, 43);
    });
    res.bench("partition: hash k=4", 1, || {
        let _ = hash_partition(&g, 4, 43);
    });

    let subs = build_all(&g, &part, &Prune::None, 42);
    res.bench("subgraph: build_all (expansion, no prune)", 1, || {
        let _ = build_all(&g, &part, &Prune::None, 43);
    });
    res.bench("subgraph: build_all (P4 retention)", 1, || {
        let _ = build_all(&g, &part, &Prune::Retention(4), 43);
    });

    let sub = subs.iter().max_by_key(|s| s.n_remote()).unwrap();
    res.bench("scoring: frequency (768 sources)", 1, || {
        let _ = scoring::frequency_scores(sub, 3, 768, 7);
    });

    // ---- tiled vs naive matmul kernels (the acceptance shape) ----------
    let (kn, kdi, kdo) = (1024usize, 256usize, 256usize);
    let mut rng = Rng::new(0xBE7C4, 0);
    let ka: Vec<f32> = (0..kn * kdi.max(kdo)).map(|_| rng.normal() as f32).collect();
    let kw: Vec<f32> = (0..kdi * kdo).map(|_| rng.normal() as f32).collect();
    let mut kout = vec![0f32; kn * kdi.max(kdo)];
    let naive_acc = res.bench("kernel: matmul_acc naive 1024x256x256", 3, || {
        kernels::naive::matmul_acc(&ka, &kw, &mut kout, kn, kdi, kdo);
    });
    let tiled_acc = res.bench("kernel: matmul_acc tiled 1024x256x256", 3, || {
        kernels::matmul_acc(&ka, &kw, &mut kout, kn, kdi, kdo);
    });
    let naive_atb = res.bench("kernel: matmul_at_b naive 1024x256x256", 3, || {
        kernels::naive::matmul_at_b(&ka, &ka, &mut kout, kn, kdi, kdo);
    });
    let tiled_atb = res.bench("kernel: matmul_at_b tiled 1024x256x256", 3, || {
        kernels::matmul_at_b(&ka, &ka, &mut kout, kn, kdi, kdo);
    });
    let naive_bwt = res.bench("kernel: matmul_b_wt naive 1024x256x256", 3, || {
        kernels::naive::matmul_b_wt(&ka, &kw, &mut kout, kn, kdi, kdo);
    });
    let tiled_bwt = res.bench("kernel: matmul_b_wt tiled 1024x256x256", 3, || {
        kernels::matmul_b_wt(&ka, &kw, &mut kout, kn, kdi, kdo);
    });
    let acc_speedup = naive_acc / tiled_acc.max(1e-12);
    println!(
        "kernel speedups vs naive: acc {:.2}x  at_b {:.2}x  b_wt {:.2}x",
        acc_speedup,
        naive_atb / tiled_atb.max(1e-12),
        naive_bwt / tiled_bwt.max(1e-12),
    );

    // sampling + assembly hot path (the per-minibatch L3 work)
    let engine = harness::make_engine(optimes::runtime::ModelKind::Gc, 5).expect("engine");
    let geom = *engine.geom();
    let dims = geom.dims();
    let mut sampler = Sampler::new(dims, 1, 0);
    let targets: Vec<u32> = sub.train_local.iter().copied().take(dims.batch).collect();
    res.bench("sampler: sample_batch (B=32, K=5, L=3)", 100, || {
        let _ = sampler.sample_batch(sub, &targets);
    });

    let adj = static_adj(&dims, dims.batch, dims.layers);
    let blocks = sampler.sample_batch(sub, &targets);
    let cache = optimes::coordinator::EmbCache::new(geom.layers - 1, geom.hidden, sub.n_remote());
    let alloc_asm = res.bench("trainer: assemble_batch alloc (B=32)", 100, || {
        let _ = assemble_batch(&blocks, sub, &cache, &g, &adj, true);
    });
    let mut scratch = BatchScratch::default();
    let scratch_asm = res.bench("trainer: BatchScratch::assemble (B=32)", 100, || {
        let _ = scratch.assemble(&blocks, sub, &cache, &g, &adj, true);
    });
    println!(
        "assembly speedup scratch vs alloc: {:.2}x",
        alloc_asm / scratch_asm.max(1e-12)
    );

    // embedding server batched RPCs (slab arena)
    let server = EmbeddingServer::new(2, geom.hidden, NetConfig::default());
    let nodes: Vec<u32> = (0..10_000u32).collect();
    let rows = vec![0.5f32; nodes.len() * geom.hidden];
    res.bench("kv: push 10k x 2 layers", 10, || {
        let _ = server.push(&nodes, &[rows.clone(), rows.clone()]);
    });
    res.bench("kv: pull 10k x 2 layers (alloc)", 10, || {
        let _ = server.pull(&nodes, false);
    });
    let mut pull_buf = Vec::new();
    res.bench("kv: pull_into 10k x 2 layers (reuse)", 10, || {
        let _ = server.pull_into(&nodes, false, &mut pull_buf);
    });

    // the same batched RPCs through the loopback TCP transport (wire
    // codec + socket overhead on top of the slab store)
    let tcp_backend = Arc::new(EmbeddingServer::new(2, geom.hidden, NetConfig::default()));
    let daemon = EmbServerDaemon::start(
        Arc::clone(&tcp_backend) as Arc<dyn EmbeddingStore>,
        "127.0.0.1:0",
    )
    .expect("loopback daemon");
    let tcp = TcpEmbeddingStore::connect(daemon.addr.to_string(), 2, geom.hidden)
        .expect("loopback connect");
    res.bench("kv: tcp push 10k x 2 layers (loopback)", 10, || {
        let _ = tcp.push(&nodes, &[rows.clone(), rows.clone()]).unwrap();
    });
    res.bench("kv: tcp pull_into 10k x 2 layers (loopback)", 10, || {
        let _ = tcp.pull_into(&nodes, false, &mut pull_buf).unwrap();
    });

    // ---- wire codecs: encode/decode throughput + bytes ratio -----------
    // (DESIGN.md §11; lands as the `wire` section of BENCH_micro.json)
    let mut wire_res = Results {
        entries: Vec::new(),
        quick,
    };
    let whidden = 32usize;
    let wrows_n = 4096usize;
    let mut wrng = Rng::new(0x51BE, 1);
    let wrows: Vec<f32> = (0..wrows_n * whidden).map(|_| wrng.normal() as f32).collect();
    let raw_payload = (wrows_n * whidden * 4) as f64;
    let mut ratios: Vec<(String, f64)> = Vec::new();
    for spec in ["raw", "f16", "bf16", "int8", "topk:8"] {
        let codec = optimes::wire::CodecKind::parse(spec).expect("bench codec").build();
        let mut enc = Vec::new();
        wire_res.bench(&format!("wire: encode {spec} 4096x32"), 20, || {
            codec.encode_rows(&wrows, whidden, &mut enc);
        });
        let mut dec = Vec::new();
        wire_res.bench(&format!("wire: decode {spec} 4096x32"), 20, || {
            codec.decode_rows(&enc, wrows_n, whidden, &mut dec).unwrap();
        });
        let ratio = raw_payload / enc.len() as f64;
        println!("wire: {spec:<8} {} B encoded, {ratio:.2}x vs raw", enc.len());
        ratios.push((format!("bytes_ratio_{}", spec.replace(':', "_")), ratio));
    }

    // ---- out-of-core graph plane: GraphFile write/load + backend scans
    // (DESIGN.md §13; lands as the `graph_io` section of BENCH_micro.json)
    let mut gio_res = Results {
        entries: Vec::new(),
        quick,
    };
    let gpath = std::env::temp_dir().join(format!("optimes-bench-{}.graph", std::process::id()));
    let mut file_mb = 0f64;
    let write_s = gio_res.bench("graph_io: write reddit-s GraphFile", 1, || {
        let info = write_graph_file(&gpath, &g).expect("bench GraphFile write");
        file_mb = info.file_len as f64 / (1024.0 * 1024.0);
    });
    let write_mb_s = file_mb / write_s.max(1e-12);
    println!("graph_io: {file_mb:.1} MB on disk, {write_mb_s:.0} MB/s streamed write");
    gio_res.bench("graph_io: load ram (verify + copy)", 1, || {
        let _ = load_graph_file(&gpath, GraphBackend::Ram).expect("bench ram load");
    });
    gio_res.bench("graph_io: open mmap (verify + map)", 1, || {
        let _ = load_graph_file(&gpath, GraphBackend::Mmap).expect("bench mmap open");
    });
    let g_ram = load_graph_file(&gpath, GraphBackend::Ram).expect("ram graph");
    let g_map = load_graph_file(&gpath, GraphBackend::Mmap).expect("mapped graph");
    for (tag, gx) in [("ram", &g_ram), ("mmap", &g_map)] {
        gio_res.bench(&format!("graph_io: full neighbor scan ({tag})"), 5, || {
            let mut acc = 0u64;
            for v in 0..gx.n as u32 {
                for &t in gx.inc.neighbors(v) {
                    acc = acc.wrapping_add(t as u64);
                }
            }
            std::hint::black_box(acc);
        });
        gio_res.bench(&format!("graph_io: feature gather 20k ({tag})"), 5, || {
            let mut acc = 0f32;
            let mut v = 1u32;
            for _ in 0..20_000 {
                v = v.wrapping_mul(0x9E37).wrapping_add(1) % gx.n as u32;
                acc += gx.feature(v)[0];
            }
            std::hint::black_box(acc);
        });
    }
    drop(g_map);
    let _ = std::fs::remove_file(&gpath);

    // engine step latency (the L1/L2 hot path through PJRT or Ref)
    let batch = assemble_batch(&blocks, sub, &cache, &g, &adj, true);
    let mut state = ModelState::init(&geom, 3);
    let eng: &Arc<dyn StepEngine> = &engine;
    res.bench(
        &format!("engine({}): train_step B=32", harness::engine_kind()),
        20,
        || {
            let _ = eng.train_step(&mut state, &batch, 0.01).unwrap();
        },
    );
    res.bench(
        &format!("engine({}): evaluate B=32", harness::engine_kind()),
        20,
        || {
            let _ = eng.evaluate(&state, &batch).unwrap();
        },
    );

    if quick {
        println!(
            "\n[micro_substrates] --quick smoke passed in {:.1}s (numbers not recorded)",
            t0.elapsed().as_secs_f64()
        );
        return;
    }
    harness::record_bench_section(
        "micro_substrates",
        res.to_json(&[
            ("matmul_acc_speedup_vs_naive", acc_speedup),
            ("matmul_at_b_speedup_vs_naive", naive_atb / tiled_atb.max(1e-12)),
            ("matmul_b_wt_speedup_vs_naive", naive_bwt / tiled_bwt.max(1e-12)),
            ("assemble_speedup_scratch_vs_alloc", alloc_asm / scratch_asm.max(1e-12)),
        ]),
    );
    let ratio_refs: Vec<(&str, f64)> = ratios.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    harness::record_bench_section("wire", wire_res.to_json(&ratio_refs));
    harness::record_bench_section(
        "graph_io",
        gio_res.to_json(&[("file_mb", file_mb), ("write_mb_per_s", write_mb_s)]),
    );
    println!("\n[micro_substrates] done in {:.1}s", t0.elapsed().as_secs_f64());
}
