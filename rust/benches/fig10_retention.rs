//! Bench target regenerating: Fig 10 — retention-limit ablation
//! (cargo bench --bench fig10_retention; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig10().expect("fig10_retention");
    println!("\n[fig10_retention] done in {:.1}s", t0.elapsed().as_secs_f64());
}
