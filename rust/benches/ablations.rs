//! Design-choice ablations called out in the paper's §1 but not given
//! their own figures, plus one framework-level ablation:
//!
//! 1. **Static vs dynamic pruning** — "we also explored and compared
//!    other variants, such as static versus dynamic graph pruning (to
//!    determine whether selecting remote nodes afresh in every round
//!    improves performance)": P4 (offline, static) vs P4dyn (re-sampled
//!    every round).
//! 2. **Push staleness** — "different staleness configurations in
//!    overlapping communication (to balance timeliness and bandwidth
//!    efficiency)": overlap pushing the ε-k state for k = 1 (paper), 2.
//! 3. **Optimizer-moment reset on broadcast** — FedAvg + client Adam
//!    interaction (DESIGN.md §10 assumption made explicit).

use std::sync::Arc;

use optimes::coordinator::{run_session, SessionMetrics, Strategy};
use optimes::harness::{self, bench_config, fmt_pct, Table};
use optimes::runtime::ModelKind;

fn summarize(t: &mut Table, label: &str, m: &SessionMetrics) {
    let p = m.median_phases();
    t.row(vec![
        label.into(),
        fmt_pct(m.peak_accuracy()),
        format!("{:.3}", m.median_round_time()),
        format!("{:.3}", p.pull),
        format!("{:.3}", p.push + p.push_hidden),
        format!("{}", m.server_embeddings),
    ]);
}

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let (p, g) = harness::load_dataset("reddit-s")?;
    let engine = harness::make_engine(ModelKind::Gc, 5)?;

    // --- 1. static vs dynamic pruning -----------------------------------
    let mut t = Table::new(&[
        "variant", "peak acc", "round(s)", "pull", "push total", "emb stored",
    ]);
    for strat in [Strategy::p(4), Strategy::p_dynamic(4)] {
        let cfg = bench_config(&p, strat.clone(), p.default_clients);
        let key = harness::session_key(
            "reddit-s",
            &strat.name,
            ModelKind::Gc,
            5,
            p.default_clients,
            cfg.rounds,
        );
        let m = harness::cached_session(&key, &g, &cfg, &engine)?;
        summarize(&mut t, &strat.name, &m);
    }
    t.print("Ablation 1 — static (P4) vs dynamic (P4dyn) pruning, reddit-s");
    println!(
        "(dynamic re-selects remote nodes each round: fresher coverage, but the\n\
         server must retain every candidate and pulls fetch the fresh subset)"
    );

    // --- 2. push staleness k=1 vs k=2 ------------------------------------
    let mut t = Table::new(&[
        "staleness", "peak acc", "round(s)", "pull", "push total", "emb stored",
    ]);
    for k in [1usize, 2] {
        let mut cfg = bench_config(&p, Strategy::o(), p.default_clients);
        cfg.overlap_stale = k;
        let key = format!(
            "{}_stale{k}",
            harness::session_key("reddit-s", "O", ModelKind::Gc, 5, p.default_clients, cfg.rounds)
        );
        let m = harness::cached_session(&key, &g, &cfg, &engine)?;
        summarize(&mut t, &format!("push ε-{k} state"), &m);
    }
    t.print("Ablation 2 — push-overlap staleness (O strategy), reddit-s");

    // --- 3. Adam-moment reset on broadcast --------------------------------
    let mut t = Table::new(&[
        "optimizer", "peak acc", "round(s)", "pull", "push total", "emb stored",
    ]);
    for reset in [true, false] {
        let mut cfg = bench_config(&p, Strategy::e(), p.default_clients);
        cfg.reset_opt_each_round = reset;
        cfg.rounds = cfg.rounds.min(12);
        let key = format!(
            "{}_optreset{reset}",
            harness::session_key("reddit-s", "E", ModelKind::Gc, 5, p.default_clients, cfg.rounds)
        );
        let m = match harness::cached_session(&key, &g, &cfg, &engine) {
            Ok(m) => m,
            Err(_) => run_session(&g, &cfg, Arc::clone(&engine))?,
        };
        summarize(
            &mut t,
            if reset { "reset m,v per round" } else { "carry m,v across rounds" },
            &m,
        );
    }
    t.print("Ablation 3 — client Adam moments across FedAvg broadcasts, reddit-s");

    println!("\n[ablations] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
