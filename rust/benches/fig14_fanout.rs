//! Bench target regenerating: Fig 14 — fanout sweep
//! (cargo bench --bench fig14_fanout; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig14().expect("fig14_fanout");
    println!("\n[fig14_fanout] done in {:.1}s", t0.elapsed().as_secs_f64());
}
