//! Bench target regenerating: Fig 6 — TTA + peak accuracy (GraphConv, all graphs)
//! (cargo bench --bench fig6_tta_accuracy; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig6(optimes::runtime::ModelKind::Gc, &["arxiv-s", "reddit-s", "products-s", "papers-s"]).expect("fig6_tta_accuracy");
    println!("\n[fig6_tta_accuracy] done in {:.1}s", t0.elapsed().as_secs_f64());
}
