//! Bench target regenerating: Fig 8 — accuracy convergence
//! (cargo bench --bench fig8_convergence; see DESIGN.md §6)
use optimes::harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    figures::fig8(optimes::runtime::ModelKind::Gc, &["arxiv-s", "reddit-s", "products-s", "papers-s"]).expect("fig8_convergence");
    println!("\n[fig8_convergence] done in {:.1}s", t0.elapsed().as_secs_f64());
}
