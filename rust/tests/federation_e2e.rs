//! End-to-end federation tests over the full coordinator stack.
//!
//! RefEngine-backed tests always run; PJRT-backed tests skip without
//! artifacts. These assert the paper's *qualitative* invariants on a tiny
//! graph: strategy semantics, footprint ordering, determinism, and the
//! overlap/prefetch mechanics surfacing in the metrics.

use std::sync::Arc;

use optimes::coordinator::metrics::RpcKind;
use optimes::coordinator::{run_session, SessionConfig, SessionMetrics, Strategy};
use optimes::graph::datasets::tiny;
use optimes::runtime::{Manifest, ModelGeom, ModelKind, RefEngine, StepEngine};

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: 16,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn cfg(strategy: Strategy, rounds: usize) -> SessionConfig {
    SessionConfig {
        strategy,
        rounds,
        epochs: 3,
        epoch_batches: 6,
        eval_batches: 6,
        lr: 0.01,
        parallel_clients: false,
        ..Default::default()
    }
}

fn run(strategy: Strategy, rounds: usize, seed: u64) -> SessionMetrics {
    let g = tiny(seed);
    run_session(&g, &cfg(strategy, rounds), ref_engine()).unwrap()
}

#[test]
fn sessions_are_deterministic() {
    let a = run(Strategy::opp(), 4, 91);
    let b = run(Strategy::opp(), 4, 91);
    assert_eq!(a.accuracies(), b.accuracies());
    assert_eq!(a.server_embeddings, b.server_embeddings);
    // phases mix modeled time (deterministic) with measured in-memory
    // service time (µs jitter) — agree to sub-millisecond
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert!((x.mean_phases.pull - y.mean_phases.pull).abs() < 1e-3);
    }
}

#[test]
fn footprint_ordering_d_p_e() {
    let d = run(Strategy::d(), 2, 93);
    let p2 = run(Strategy::parse("P2").unwrap(), 2, 93);
    let p4 = run(Strategy::p(4), 2, 93);
    let e = run(Strategy::e(), 2, 93);
    assert_eq!(d.server_embeddings, 0);
    assert!(p2.server_embeddings <= p4.server_embeddings);
    assert!(p4.server_embeddings <= e.server_embeddings);
    assert!(e.server_embeddings > 0);
    // retained remotes follow the same ladder
    assert!(p2.retained_remotes <= p4.retained_remotes);
    assert!(p4.retained_remotes <= e.retained_remotes);
    // pull volume ordering shows up in the modeled pull time
    let pull = |m: &SessionMetrics| m.median_phases().pull;
    assert_eq!(pull(&d), 0.0);
    assert!(pull(&p2) <= pull(&e) + 1e-12);
}

#[test]
fn overlap_reduces_visible_push() {
    let e = run(Strategy::e(), 3, 95);
    let o = run(Strategy::o(), 3, 95);
    // O's visible push must be below E's (most of it hides under the
    // final epoch), and hidden push must appear.
    assert!(o.median_phases().push <= e.median_phases().push + 1e-9);
    let hidden: f64 = o.rounds.iter().map(|r| r.mean_phases.push_hidden).sum();
    assert!(hidden > 0.0, "overlap never hid any push work");
    let e_hidden: f64 = e.rounds.iter().map(|r| r.mean_phases.push_hidden).sum();
    assert_eq!(e_hidden, 0.0);
}

#[test]
fn opp_splits_pull_between_prefetch_and_on_demand() {
    let e = run(Strategy::e(), 3, 97);
    let opp = run(Strategy::opp(), 3, 97);
    // initial pull strictly smaller (only top-25% prefetched)
    assert!(opp.median_phases().pull < e.median_phases().pull);
    // and on-demand pulls appear with bounded RPC count
    let dyn_rpcs = opp.rpcs(RpcKind::PullOnDemand);
    assert!(!dyn_rpcs.is_empty());
    // at most one on-demand RPC per minibatch
    let max_rpcs = 3 /*rounds*/ * 3 /*epochs*/ * 6 /*batches*/ * 4 /*clients*/;
    assert!(dyn_rpcs.len() <= max_rpcs);
    // E never pulls on demand
    assert!(e.rpcs(RpcKind::PullOnDemand).is_empty());
}

#[test]
fn opg_prunes_but_still_exchanges() {
    let e = run(Strategy::e(), 3, 99);
    let opg = run(Strategy::opg(), 3, 99);
    assert!(opg.retained_remotes < e.retained_remotes);
    assert!(opg.server_embeddings > 0);
    assert!(opg.median_phases().pull < e.median_phases().pull);
}

#[test]
fn accuracy_improves_over_training() {
    let m = run(Strategy::e(), 10, 101);
    let smoothed = m.smoothed_accuracies();
    let early = smoothed[1];
    let late = *smoothed.last().unwrap();
    assert!(
        late > early + 0.05,
        "no learning: early {early:.3} late {late:.3}"
    );
}

#[test]
fn parallel_clients_run_concurrently_and_converge() {
    let g = tiny(103);
    let mut c = cfg(Strategy::o(), 5);
    c.parallel_clients = true;
    let m = run_session(&g, &c, ref_engine()).unwrap();
    assert_eq!(m.rounds.len(), 5);
    assert!(m.rounds.iter().all(|r| r.clients.len() == 4));
    assert!(m.peak_accuracy() > 0.3);
}

#[test]
fn pjrt_end_to_end_session() {
    // full stack through the real AOT artifacts (skips without them)
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let Ok(manifest) = Manifest::load(dir) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine: Arc<dyn StepEngine> = Arc::new(
        optimes::runtime::PjrtEngine::start(&manifest, ModelKind::Gc, 5).unwrap(),
    );
    let g = tiny(105);
    let cfg = SessionConfig {
        strategy: Strategy::opp(),
        rounds: 3,
        epochs: 2,
        epoch_batches: 3,
        eval_batches: 4,
        lr: 0.01,
        parallel_clients: true,
        ..Default::default()
    };
    let m = run_session(&g, &cfg, engine).unwrap();
    assert_eq!(m.rounds.len(), 3);
    assert!(m.rounds.iter().all(|r| r.accuracy.is_finite()));
    assert!(m.server_embeddings > 0);
}
