//! The multi-tenant embedding service, end to end (DESIGN.md §15):
//! connect/disconnect churn against a live daemon with bounded handler
//! threads and consistent stats (the reaping bugfix), admission control
//! (`max_conns` / `max_inflight`) answering over-cap work with a *named*
//! `BUSY` error instead of a hang or a silent drop, tenant namespaces
//! isolating concurrent federated sessions on one shared daemon
//! bit-for-bit, and latency-aware replica selection staying bit-identical
//! to primary-first at zero injected latency.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use optimes::coordinator::{
    DaemonConfig, EmbServerDaemon, EmbeddingServer, EmbeddingStore, Fault, FaultStore, NetConfig,
    RemoteEmbClient, ReplicaSelect, SessionBuilder, SessionConfig, SessionMetrics, ShardedStore,
    Strategy, TcpEmbeddingStore,
};
use optimes::graph::datasets::tiny;
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};
use optimes::wire::CodecKind;

const HIDDEN: usize = 16;
const N_LAYERS: usize = 2; // layers - 1

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: HIDDEN,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn cfg(strategy: Strategy, rounds: usize) -> SessionConfig {
    SessionConfig {
        strategy,
        rounds,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        // sequential clients: a deterministic push/pull order makes the
        // accuracy curves comparable bit-for-bit across backends
        parallel_clients: false,
        ..Default::default()
    }
}

fn run_with_store(
    store: Arc<dyn EmbeddingStore>,
    strategy: Strategy,
    rounds: usize,
    seed: u64,
) -> SessionMetrics {
    let g = tiny(seed);
    SessionBuilder::new(cfg(strategy, rounds))
        .store(store)
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap()
}

fn assert_same_curve(a: &SessionMetrics, b: &SessionMetrics) {
    assert_eq!(a.accuracies(), b.accuracies(), "accuracy curves diverged");
    assert_eq!(a.server_embeddings, b.server_embeddings);
    let va: Vec<f64> = a.rounds.iter().map(|r| r.val_loss).collect();
    let vb: Vec<f64> = b.rounds.iter().map(|r| r.val_loss).collect();
    assert_eq!(va, vb, "validation losses diverged");
}

fn slab() -> Arc<dyn EmbeddingStore> {
    Arc::new(EmbeddingServer::new(N_LAYERS, HIDDEN, NetConfig::default()))
}

fn daemon_with(config: DaemonConfig) -> EmbServerDaemon {
    EmbServerDaemon::start_with(slab(), "127.0.0.1:0", config).unwrap()
}

fn rows(nodes: &[u32], salt: f32) -> Vec<f32> {
    nodes
        .iter()
        .flat_map(|&n| (0..HIDDEN).map(move |j| n as f32 + j as f32 * 0.25 + salt))
        .collect()
}

/// Poll until the daemon reports `live_conns == 0 && handler_threads ==
/// 0` (panics after `secs` seconds — a handler-thread leak).
fn await_drained(d: &EmbServerDaemon, secs: u64) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(secs);
    loop {
        let s = d.stats();
        if s.live_conns == 0 && s.handler_threads == 0 {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "daemon never drained (handler-thread leak?): {s:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}

// ---------------------------------------------------------------------------
// connection churn + admission control
// ---------------------------------------------------------------------------

#[test]
fn connection_churn_keeps_handler_threads_bounded() {
    let d = daemon_with(DaemonConfig::default());
    const CYCLES: usize = 300;
    for i in 0..CYCLES {
        let mut c = RemoteEmbClient::connect(d.addr, N_LAYERS, HIDDEN).unwrap();
        let nodes = [i as u32];
        c.push(&nodes, &[rows(&nodes, 0.0), rows(&nodes, 1.0)]).unwrap();
        let (got, _) = c.pull(&nodes).unwrap();
        assert_eq!(got[0], rows(&nodes, 0.0));
        // the gauge may lag (the sweep runs on the accept thread), but
        // strictly sequential clients can never stack up hundreds deep
        assert!(
            d.stats().handler_threads <= 64,
            "handler threads grew without bound at cycle {i}: {:?}",
            d.stats()
        );
        drop(c);
    }
    await_drained(&d, 10);
    let s = d.stats();
    assert_eq!(s.total_conns, CYCLES, "{s:?}");
    assert_eq!(s.rejected_conns, 0, "{s:?}");
    assert!(s.peak_conns >= 1, "{s:?}");
    d.shutdown();
}

#[test]
fn max_conns_cap_rejects_loudly_and_slots_free_on_disconnect() {
    let d = daemon_with(DaemonConfig {
        max_conns: 2,
        max_inflight: 0,
    });
    // fill both slots (a served stats round-trip proves admission)
    let mut a = RemoteEmbClient::connect(d.addr, N_LAYERS, HIDDEN).unwrap();
    a.stats().unwrap();
    let mut b = RemoteEmbClient::connect(d.addr, N_LAYERS, HIDDEN).unwrap();
    b.stats().unwrap();
    // the third client gets a named BUSY, not a hang or a bare I/O error
    let mut c = RemoteEmbClient::connect(d.addr, N_LAYERS, HIDDEN).unwrap();
    let err = c.stats().expect_err("third connection must be rejected");
    assert!(format!("{err:#}").contains("BUSY"), "{err:#}");
    assert!(d.stats().rejected_conns >= 1, "{:?}", d.stats());
    // dropping an admitted client frees its slot: a newcomer gets in
    // once the handler notices the hangup (bounded read timeout + sweep)
    drop(a);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let mut fresh = RemoteEmbClient::connect(d.addr, N_LAYERS, HIDDEN).unwrap();
        if fresh.stats().is_ok() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "freed slot never became available: {:?}",
            d.stats()
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    // the surviving admitted client kept full service throughout
    b.push(&[9], &[rows(&[9], 0.0), rows(&[9], 1.0)]).unwrap();
    d.shutdown();
}

#[test]
fn max_inflight_cap_sheds_excess_requests_with_busy() {
    // every data-plane op stalls 200ms for real, so one op holds the
    // single in-flight slot long enough for concurrent ops to collide
    let slow: Arc<dyn EmbeddingStore> = Arc::new(
        FaultStore::new(
            slab(),
            "slow",
            vec![Fault::DelayEvery {
                every: 1,
                secs: 0.2,
            }],
        )
        .with_real_delays(),
    );
    let d = EmbServerDaemon::start_with(
        slow,
        "127.0.0.1:0",
        DaemonConfig {
            max_conns: 0,
            max_inflight: 1,
        },
    )
    .unwrap();
    let addr = d.addr;
    let busy = AtomicUsize::new(0);
    let served = AtomicUsize::new(0);
    // 4 threads hammering a 1-slot daemon: ops overlap by construction
    // (each successful op holds the slot for 200ms while the other
    // threads immediately re-issue), so sheds are inevitable — and every
    // shed must be the *named* BUSY, never a hang or a bare I/O error
    std::thread::scope(|scope| {
        for t in 0..4u32 {
            let (busy, served) = (&busy, &served);
            scope.spawn(move || {
                let mut c = RemoteEmbClient::connect(addr, N_LAYERS, HIDDEN).unwrap();
                for _ in 0..5 {
                    match c.pull(&[t]) {
                        Ok(_) => {
                            served.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(e) => {
                            assert!(format!("{e:#}").contains("BUSY"), "{e:#}");
                            busy.fetch_add(1, Ordering::SeqCst);
                            // the server drops a shed connection after
                            // draining it: reconnect and keep hammering
                            c = RemoteEmbClient::connect(addr, N_LAYERS, HIDDEN).unwrap();
                        }
                    }
                }
            });
        }
    });
    assert!(busy.load(Ordering::SeqCst) >= 1, "no request was ever shed");
    assert!(served.load(Ordering::SeqCst) >= 1, "no request was ever served");
    let s = d.stats();
    assert_eq!(s.rejected_requests, busy.load(Ordering::SeqCst), "{s:?}");
    assert!(s.peak_inflight <= 1, "{s:?}");
    // once the hammering stops, the slot frees and service resumes
    let mut after = RemoteEmbClient::connect(addr, N_LAYERS, HIDDEN).unwrap();
    let (got, _) = after.pull(&[424242]).unwrap();
    assert!(got[0].iter().all(|&v| v == 0.0));
    d.shutdown();
}

#[test]
fn busy_rejection_surfaces_named_through_tcp_store() {
    let d = daemon_with(DaemonConfig {
        max_conns: 1,
        max_inflight: 0,
    });
    // the first store's geometry handshake occupies the only slot
    let held = TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    // the second store's handshake must fail with the named BUSY (the
    // server drains before closing, so the verdict isn't lost to an RST)
    let err = TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN)
        .expect_err("second store must be rejected at the connection cap");
    assert!(format!("{err:#}").contains("BUSY"), "{err:#}");
    drop(held);
    d.shutdown();
}

// ---------------------------------------------------------------------------
// tenant isolation: bit-identical sessions on shared infrastructure
// ---------------------------------------------------------------------------

#[test]
fn two_tenant_sessions_on_one_daemon_match_two_dedicated_daemons() {
    // reference: each session on its own dedicated (untenanted) daemon
    let d_alice = daemon_with(DaemonConfig::default());
    let d_bob = daemon_with(DaemonConfig::default());
    let ref_alice = run_with_store(
        Arc::new(TcpEmbeddingStore::connect(d_alice.addr.to_string(), N_LAYERS, HIDDEN).unwrap()),
        Strategy::opp(),
        3,
        311,
    );
    let ref_bob = run_with_store(
        Arc::new(TcpEmbeddingStore::connect(d_bob.addr.to_string(), N_LAYERS, HIDDEN).unwrap()),
        Strategy::opp(),
        3,
        312,
    );
    d_alice.shutdown();
    d_bob.shutdown();

    // shared: both sessions run *concurrently* against ONE daemon,
    // isolated only by their tenant namespaces
    let shared = daemon_with(DaemonConfig::default());
    let addr = shared.addr.to_string();
    let connect = |tenant: &str| -> Arc<dyn EmbeddingStore> {
        Arc::new(
            TcpEmbeddingStore::connect_opts(
                addr.clone(),
                N_LAYERS,
                HIDDEN,
                CodecKind::Raw,
                Some(tenant.to_string()),
            )
            .unwrap(),
        )
    };
    let (got_alice, got_bob) = std::thread::scope(|scope| {
        let sa = connect("alice");
        let sb = connect("bob");
        let ha = scope.spawn(move || run_with_store(sa, Strategy::opp(), 3, 311));
        let hb = scope.spawn(move || run_with_store(sb, Strategy::opp(), 3, 312));
        (ha.join().unwrap(), hb.join().unwrap())
    });
    assert_eq!(shared.stats().tenants, 2, "{:?}", shared.stats());
    shared.shutdown();

    assert_same_curve(&got_alice, &ref_alice);
    assert_same_curve(&got_bob, &ref_bob);
}

// ---------------------------------------------------------------------------
// latency-aware replica selection: a routing policy, never a value change
// ---------------------------------------------------------------------------

#[test]
fn replica_selection_policies_are_bit_identical_at_zero_latency() {
    let replicated = |select: ReplicaSelect| -> Arc<dyn EmbeddingStore> {
        let backends: Vec<Arc<dyn EmbeddingStore>> = (0..4).map(|_| slab()).collect();
        Arc::new(
            ShardedStore::replicated(backends, 1)
                .unwrap()
                .with_replica_select(select),
        )
    };
    let fastest = run_with_store(replicated(ReplicaSelect::Fastest), Strategy::opp(), 3, 271);
    let primary = run_with_store(replicated(ReplicaSelect::Primary), Strategy::opp(), 3, 271);
    assert_same_curve(&fastest, &primary);
}
