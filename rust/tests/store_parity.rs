//! The transport-agnostic embedding plane, end to end: the wire protocol
//! over loopback (including empty and larger-than-push-batch payloads),
//! and the acceptance checks that a federated session produces the exact
//! same accuracy curve no matter which [`EmbeddingStore`] backend carries
//! the embeddings — in-process slab, `TcpEmbeddingStore` against an
//! in-test daemon, `TcpEmbeddingStore` against a *spawned* `optimes
//! serve` process, a 4-way `ShardedStore`, and a replicated (R=1)
//! 4-way `ShardedStore` — and no matter whether the asynchronous
//! pipeline is on or off (`--pipeline`, DESIGN.md §9): overlap may
//! change wall time, never results. (Fault-injected runs have their own
//! suite, `tests/fault_tolerance.rs`.)
//!
//! The suite is also the **wire-codec parity matrix** (DESIGN.md §11):
//! every session store is wrapped per `OPTIMES_WIRE_CODEC` (the CI
//! `wire-codec` job reruns the whole file as a `raw|int8` matrix — a
//! codec may shape values, but it must shape them *identically* on
//! every backend), and the dedicated tests below pin raw-vs-delta
//! bit-parity, cross-backend parity for `f16`/`int8` (in-process
//! decorator vs TCP handshake vs sharded compound), and the ≥3×
//! compression / ≤1-point accuracy acceptance criteria.

use std::sync::Arc;

use optimes::coordinator::{
    EmbServerDaemon, EmbeddingServer, EmbeddingStore, NetConfig, RemoteEmbClient, SessionBuilder,
    SessionConfig, SessionMetrics, ShardedStore, Strategy, TcpEmbeddingStore, ThrottledStore,
};
use optimes::graph::datasets::tiny;
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};
use optimes::wire::{self, CodecSpec};

const HIDDEN: usize = 16;
const N_LAYERS: usize = 2; // layers - 1

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: HIDDEN,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn cfg(strategy: Strategy, rounds: usize) -> SessionConfig {
    SessionConfig {
        strategy,
        rounds,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        // sequential clients: a deterministic push/pull order makes the
        // accuracy curves comparable bit-for-bit across backends
        parallel_clients: false,
        ..Default::default()
    }
}

/// A fresh in-process slab at the suite geometry (what the builder's
/// default store would be).
fn in_proc() -> Arc<dyn EmbeddingStore> {
    Arc::new(EmbeddingServer::new(N_LAYERS, HIDDEN, NetConfig::default()))
}

/// Wrap a backend per `OPTIMES_WIRE_CODEC` — the CI wire-codec matrix
/// reruns this whole suite under `raw|int8`; every backend gets the
/// same wrapping, so cross-backend parity must hold under any codec.
fn wire_wrap(store: Arc<dyn EmbeddingStore>) -> Arc<dyn EmbeddingStore> {
    wire::wrap_from_env(store, NetConfig::default())
}

/// Run one session on `tiny(seed)` against an explicit store, exactly
/// as given (no environment wrapping — the codec tests compose their
/// own planes).
fn run_with_store(
    store: Arc<dyn EmbeddingStore>,
    strategy: Strategy,
    rounds: usize,
    seed: u64,
    pipeline: Option<bool>,
) -> SessionMetrics {
    let g = tiny(seed);
    let mut c = cfg(strategy, rounds);
    if let Some(p) = pipeline {
        c.pipeline = p;
    }
    SessionBuilder::new(c)
        .store(store)
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap()
}

/// Run one session on `tiny(seed)` against the given store (None = a
/// fresh in-process server), wrapped per the environment wire codec.
fn run_with(
    store: Option<Arc<dyn EmbeddingStore>>,
    strategy: Strategy,
    rounds: usize,
    seed: u64,
) -> SessionMetrics {
    let store = wire_wrap(store.unwrap_or_else(in_proc));
    run_with_store(store, strategy, rounds, seed, None)
}

/// Like [`run_with`], with the async pipeline forced on or off.
fn run_with_pipeline(
    store: Option<Arc<dyn EmbeddingStore>>,
    strategy: Strategy,
    rounds: usize,
    seed: u64,
    pipeline: bool,
) -> SessionMetrics {
    let store = wire_wrap(store.unwrap_or_else(in_proc));
    run_with_store(store, strategy, rounds, seed, Some(pipeline))
}

fn assert_same_curve(a: &SessionMetrics, b: &SessionMetrics) {
    assert_eq!(
        a.accuracies(),
        b.accuracies(),
        "accuracy curves diverged between store backends"
    );
    assert_eq!(a.server_embeddings, b.server_embeddings);
    let va: Vec<f64> = a.rounds.iter().map(|r| r.val_loss).collect();
    let vb: Vec<f64> = b.rounds.iter().map(|r| r.val_loss).collect();
    assert_eq!(va, vb, "validation losses diverged between store backends");
}

// ---------------------------------------------------------------------------
// wire-protocol edges over loopback
// ---------------------------------------------------------------------------

fn daemon(hidden: usize) -> (EmbServerDaemon, Arc<EmbeddingServer>) {
    let server = Arc::new(EmbeddingServer::new(N_LAYERS, hidden, NetConfig::default()));
    let d = EmbServerDaemon::start(
        Arc::clone(&server) as Arc<dyn EmbeddingStore>,
        "127.0.0.1:0",
    )
    .unwrap();
    (d, server)
}

#[test]
fn wire_empty_push_pull_stats() {
    let (d, _server) = daemon(4);
    let mut c = RemoteEmbClient::connect(d.addr, N_LAYERS, 4).unwrap();
    // empty payloads are legal frames, not protocol errors
    let rec = c.push(&[], &[Vec::new(), Vec::new()]).unwrap();
    assert_eq!(rec.rows, 0);
    let (got, rec) = c.pull(&[]).unwrap();
    assert_eq!(rec.rows, 0);
    assert_eq!(got.len(), N_LAYERS);
    assert!(got.iter().all(|l| l.is_empty()));
    let s = c.stats().unwrap();
    assert_eq!((s.nodes, s.rows), (0, 0));
    // and the connection still serves real traffic afterwards
    c.push(&[7], &[vec![1.0; 4], vec![2.0; 4]]).unwrap();
    let s = c.stats().unwrap();
    assert_eq!((s.nodes, s.rows, s.failovers, s.epoch), (1, 2, 0, 0));
    d.shutdown();
}

#[test]
fn wire_batches_larger_than_push_batch() {
    // one frame far beyond the engine's push_batch (8): the protocol is
    // framed by explicit lengths, not by geometry
    let (d, server) = daemon(4);
    let mut c = RemoteEmbClient::connect(d.addr, N_LAYERS, 4).unwrap();
    let nodes: Vec<u32> = (0..10_000).collect();
    let rows: Vec<f32> = (0..nodes.len() * 4).map(|i| i as f32 * 0.5).collect();
    c.push(&nodes, &[rows.clone(), rows.clone()]).unwrap();
    let (got, _) = c.pull(&nodes).unwrap();
    assert_eq!(got[0], rows);
    assert_eq!(got[1], rows);
    assert_eq!(server.stored_nodes(), 10_000);
    d.shutdown();
}

// ---------------------------------------------------------------------------
// session-level backend parity (the acceptance criteria)
// ---------------------------------------------------------------------------

#[test]
fn tcp_store_session_matches_in_process() {
    let (d, _server) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let in_proc = run_with(None, Strategy::opp(), 4, 111);
    let over_tcp = run_with(Some(Arc::new(tcp)), Strategy::opp(), 4, 111);
    assert_same_curve(&in_proc, &over_tcp);
    // (`contains`, not equality: the CI wire-codec matrix adds wrapper
    // prefixes like `wire(int8 over ...)` to both backends)
    assert!(over_tcp.store_backend.contains("tcp("));
    assert!(in_proc.store_backend.contains("in-process"));
    // OPP exercises both the prefetch pull and the on-demand path, so
    // both curves must have seen real communication
    assert!(over_tcp.server_embeddings > 0);
    d.shutdown();
}

#[test]
fn sharded_store_session_matches_in_process() {
    let sharded = ShardedStore::in_process(4, N_LAYERS, HIDDEN, NetConfig::default());
    let in_proc = run_with(None, Strategy::opp(), 4, 113);
    let over_shards = run_with(Some(Arc::new(sharded)), Strategy::opp(), 4, 113);
    assert_same_curve(&in_proc, &over_shards);
    assert!(over_shards.store_backend.contains("sharded(4 shards"));
}

#[test]
fn replicated_store_session_matches_in_process() {
    // R=1: every row lives on two backends; replication must be
    // invisible to the training loop (values, occupancy, curve)
    let replicated =
        ShardedStore::in_process_replicated(4, 1, N_LAYERS, HIDDEN, NetConfig::default()).unwrap();
    let in_proc = run_with(None, Strategy::opp(), 4, 123);
    let over_replicas = run_with(Some(Arc::new(replicated)), Strategy::opp(), 4, 123);
    assert_same_curve(&in_proc, &over_replicas);
    assert!(
        over_replicas.store_backend.contains("1 replica"),
        "{}",
        over_replicas.store_backend
    );
    // a fault-free replicated run absorbs no failovers
    assert_eq!(over_replicas.total_failovers(), 0);
}

#[test]
fn sharded_tcp_daemons_session_matches_in_process() {
    // four separate daemons, each fronting its own slab — the full
    // "multiple remote stores" deployment, hash-partitioned by the client
    let daemons: Vec<(EmbServerDaemon, Arc<EmbeddingServer>)> =
        (0..4).map(|_| daemon(HIDDEN)).collect();
    let backends: Vec<Arc<dyn EmbeddingStore>> = daemons
        .iter()
        .map(|(d, _)| {
            Arc::new(TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN).unwrap())
                as Arc<dyn EmbeddingStore>
        })
        .collect();
    let sharded = ShardedStore::new(backends).unwrap();
    let in_proc = run_with(None, Strategy::e(), 3, 117);
    let federated = run_with(Some(Arc::new(sharded)), Strategy::e(), 3, 117);
    assert_same_curve(&in_proc, &federated);
    // every daemon ended up owning a non-trivial share of the embeddings
    let total: usize = daemons.iter().map(|(_, s)| s.stored_nodes()).sum();
    assert_eq!(total, in_proc.server_embeddings);
    for (_, s) in &daemons {
        assert!(s.stored_nodes() > 0, "a shard owned no embeddings");
    }
    for (d, _) in daemons {
        d.shutdown();
    }
}

// ---------------------------------------------------------------------------
// against a real spawned `optimes serve` process
// ---------------------------------------------------------------------------

/// Kills the child even when an assertion fails mid-test.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn session_through_spawned_serve_process_matches_in_process() {
    use std::io::BufRead;
    let exe = env!("CARGO_BIN_EXE_optimes");
    let mut child = ChildGuard(
        std::process::Command::new(exe)
            .args([
                "serve",
                "--port",
                "0",
                "--layers",
                &N_LAYERS.to_string(),
                "--hidden",
                &HIDDEN.to_string(),
            ])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn optimes serve"),
    );
    let stdout = child.0.stdout.take().expect("child stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = None;
    for _ in 0..20 {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(pos) = line.find("listening on ") {
            let rest = &line[pos + "listening on ".len()..];
            addr = rest.split_whitespace().next().map(|s| s.to_string());
            break;
        }
    }
    let addr = addr.expect("serve process never reported its bound address");
    let tcp = TcpEmbeddingStore::connect(addr, N_LAYERS, HIDDEN).unwrap();
    let in_proc = run_with(None, Strategy::e(), 3, 119);
    let remote = run_with(Some(Arc::new(tcp)), Strategy::e(), 3, 119);
    assert_same_curve(&in_proc, &remote);
}

// ---------------------------------------------------------------------------
// async-pipeline parity: --pipeline on|off must be bit-identical on every
// backend for a fixed seed (overlap changes wall time, never results)
// ---------------------------------------------------------------------------

#[test]
fn pipeline_parity_in_process() {
    let off = run_with_pipeline(None, Strategy::opp(), 4, 211, false);
    let on = run_with_pipeline(None, Strategy::opp(), 4, 211, true);
    assert_same_curve(&off, &on);
    assert!(on.pipelined && !off.pipelined);
    let ov = on.overlap_stats();
    assert!(ov.pipelined, "pipelined session consumed no tickets");
    assert!(ov.push_wall > 0.0, "no measured push pipeline wall");
    assert_eq!(off.overlap_stats(), Default::default());
}

#[test]
fn pipeline_parity_tcp() {
    // fresh daemon per session: both runs must start on an empty store
    let (d_off, _s) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d_off.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let off = run_with_pipeline(Some(Arc::new(tcp)), Strategy::opp(), 4, 213, false);
    d_off.shutdown();

    let (d_on, _s) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d_on.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let on = run_with_pipeline(Some(Arc::new(tcp)), Strategy::opp(), 4, 213, true);
    assert_same_curve(&off, &on);
    let ov = on.overlap_stats();
    assert!(ov.pipelined);
    assert!(ov.push_wall > 0.0);
    assert!(ov.queue_peak >= 1);
    d_on.shutdown();
}

#[test]
fn pipeline_parity_4shard() {
    let mk = || -> Arc<dyn EmbeddingStore> {
        Arc::new(ShardedStore::in_process(4, N_LAYERS, HIDDEN, NetConfig::default()))
    };
    let off = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 217, false);
    let on = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 217, true);
    assert_same_curve(&off, &on);
    assert!(on.overlap_stats().pipelined);
}

#[test]
fn pipeline_parity_replicated_4shard() {
    let mk = || -> Arc<dyn EmbeddingStore> {
        Arc::new(
            ShardedStore::in_process_replicated(4, 1, N_LAYERS, HIDDEN, NetConfig::default())
                .unwrap(),
        )
    };
    let off = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 223, false);
    let on = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 223, true);
    assert_same_curve(&off, &on);
    assert!(on.overlap_stats().pipelined);
}

#[test]
fn pipeline_overlap_is_real_under_throttled_store() {
    // sleep out the netsim cost model so store RPCs consume real wall
    // time: the pipelined session must measurably hide push/pull work
    // under training + aggregation while producing identical results
    let slow = NetConfig {
        latency: 0.02,
        ..NetConfig::default()
    };
    let mk = || -> Arc<dyn EmbeddingStore> {
        Arc::new(ThrottledStore::new(Arc::new(EmbeddingServer::new(N_LAYERS, HIDDEN, slow))))
    };
    let off = run_with_pipeline(Some(mk()), Strategy::o(), 3, 219, false);
    let on = run_with_pipeline(Some(mk()), Strategy::o(), 3, 219, true);
    assert_same_curve(&off, &on);
    let ov = on.overlap_stats();
    assert!(ov.pipelined);
    assert!(ov.overlap_saved > 0.0, "pipeline hid no real work: {ov:?}");
    // the real measurement and the virtual model agree that work was
    // hidden (they need not agree on the amount)
    let virtual_hidden: f64 = on.rounds.iter().map(|r| r.mean_phases.push_hidden).sum();
    assert!(virtual_hidden > 0.0);
}

// ---------------------------------------------------------------------------
// the wire-codec dimension of the parity matrix (DESIGN.md §11)
// ---------------------------------------------------------------------------

#[test]
fn raw_delta_session_is_bit_identical_and_never_moves_more() {
    // the lossless-plane acceptance criterion: raw vs raw+delta follow
    // the exact same curve (delta only elides bit-identical rows), and
    // the delta run never puts more bytes on the wire
    for pipeline in [false, true] {
        let raw = run_with_store(in_proc(), Strategy::e(), 4, 231, Some(pipeline));
        let spec = CodecSpec::parse("raw,delta").unwrap();
        let delta = run_with_store(
            spec.wrap_store(in_proc(), NetConfig::default()),
            Strategy::e(),
            4,
            231,
            Some(pipeline),
        );
        assert_same_curve(&raw, &delta);
        assert_eq!(delta.wire_codec, "raw+delta");
        assert!(raw.total_bytes_tx() > 0);
        assert!(delta.total_bytes_tx() <= raw.total_bytes_tx());
        // the raw baseline credits elided rows, so the ratio never
        // reads below 1
        assert!(delta.wire_ratio() >= 1.0 - 1e-9);
    }
}

#[test]
fn codec_parity_across_in_process_tcp_and_sharded() {
    // a lossy codec may shape values — but identically on every
    // backend: the CodecStore round-trip, the negotiated TCP
    // connection, and the sharded compound must produce bit-identical
    // accuracy curves (and move the same number of encoded bytes)
    for name in ["f16", "int8"] {
        let spec = CodecSpec::parse(name).unwrap();
        let wrapped = run_with_store(
            spec.wrap_store(in_proc(), NetConfig::default()),
            Strategy::opp(),
            3,
            229,
            None,
        );
        let (d, _server) = daemon(HIDDEN);
        let tcp = TcpEmbeddingStore::connect_with_codec(
            d.addr.to_string(),
            N_LAYERS,
            HIDDEN,
            spec.codec.clone(),
        )
        .unwrap();
        let over_tcp = run_with_store(Arc::new(tcp), Strategy::opp(), 3, 229, None);
        let sharded = spec.wrap_store(
            Arc::new(ShardedStore::in_process(4, N_LAYERS, HIDDEN, NetConfig::default())),
            NetConfig::default(),
        );
        let over_shards = run_with_store(sharded, Strategy::opp(), 3, 229, None);

        assert_same_curve(&wrapped, &over_tcp);
        assert_same_curve(&wrapped, &over_shards);
        // and the meters agree on the encoded traffic, backend-invariant
        assert!(wrapped.total_bytes_tx() > 0, "{name}: no bytes metered");
        assert_eq!(wrapped.total_bytes_tx(), over_tcp.total_bytes_tx(), "{name}");
        assert_eq!(wrapped.total_bytes_tx(), over_shards.total_bytes_tx(), "{name}");
        assert_eq!(wrapped.total_bytes_rx(), over_tcp.total_bytes_rx(), "{name}");
        assert_eq!(wrapped.wire_codec, name);
        assert_eq!(over_tcp.wire_codec, name);
        d.shutdown();
    }
}

#[test]
fn lossy_codecs_compress_3x_within_a_point() {
    // the headline acceptance criterion, at the CLI default geometry
    // (hidden 32, where int8 is 3.2x and topk:7 is 3.05x on payload
    // bytes): a fixed session pushes >= 3x fewer bytes while the peak
    // smoothed accuracy stays within one point of the raw run
    const H: usize = 32;
    let engine = || -> Arc<dyn StepEngine> {
        Arc::new(RefEngine::new(ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: H,
            classes: 4,
            batch: 8,
            fanout: 3,
            push_batch: 8,
        }))
    };
    let run = |spec: Option<&str>| -> SessionMetrics {
        let g = tiny(401);
        let base: Arc<dyn EmbeddingStore> =
            Arc::new(EmbeddingServer::new(N_LAYERS, H, NetConfig::default()));
        let store = match spec {
            Some(s) => CodecSpec::parse(s)
                .unwrap()
                .wrap_store(base, NetConfig::default()),
            None => base,
        };
        SessionBuilder::new(cfg(Strategy::e(), 10))
            .store(store)
            .build(&g, engine())
            .unwrap()
            .run()
            .unwrap()
    };
    let raw = run(None);
    let raw_tx = raw.total_bytes_tx();
    assert!(raw_tx > 0, "raw run metered no push bytes");
    assert_eq!(raw.wire_codec, "raw");
    for s in ["int8", "topk:7"] {
        let m = run(Some(s));
        assert_eq!(m.wire_codec, s);
        assert!(
            m.total_bytes_tx() * 3 <= raw_tx,
            "{s}: pushed {} bytes, raw pushed {raw_tx} (< 3x saving)",
            m.total_bytes_tx()
        );
        let drift = (m.peak_accuracy() - raw.peak_accuracy()).abs();
        assert!(
            drift <= 0.01 + 1e-9,
            "{s}: peak accuracy drifted {drift:.4} (> 1 point) from the raw run"
        );
    }
}

#[test]
fn tcp_store_works_with_parallel_clients() {
    // parallel clients share the pooled TCP store: results must still be
    // structurally sound (bit-parity is only guaranteed sequentially)
    let (d, _server) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let g = tiny(121);
    let mut c = cfg(Strategy::o(), 3);
    c.parallel_clients = true;
    let m = SessionBuilder::new(c)
        .store(Arc::new(tcp))
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(m.rounds.len(), 3);
    assert!(m.rounds.iter().all(|r| r.accuracy.is_finite()));
    assert!(m.server_embeddings > 0);
    d.shutdown();
}
