//! The transport-agnostic embedding plane, end to end: the wire protocol
//! over loopback (including empty and larger-than-push-batch payloads),
//! and the acceptance checks that a federated session produces the exact
//! same accuracy curve no matter which [`EmbeddingStore`] backend carries
//! the embeddings — in-process slab, `TcpEmbeddingStore` against an
//! in-test daemon, `TcpEmbeddingStore` against a *spawned* `optimes
//! serve` process, a 4-way `ShardedStore`, and a replicated (R=1)
//! 4-way `ShardedStore` — and no matter whether the asynchronous
//! pipeline is on or off (`--pipeline`, DESIGN.md §9): overlap may
//! change wall time, never results. (Fault-injected runs have their own
//! suite, `tests/fault_tolerance.rs`.)

use std::sync::Arc;

use optimes::coordinator::{
    EmbServerDaemon, EmbeddingServer, EmbeddingStore, NetConfig, RemoteEmbClient, SessionBuilder,
    SessionConfig, SessionMetrics, ShardedStore, Strategy, TcpEmbeddingStore, ThrottledStore,
};
use optimes::graph::datasets::tiny;
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};

const HIDDEN: usize = 16;
const N_LAYERS: usize = 2; // layers - 1

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: HIDDEN,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn cfg(strategy: Strategy, rounds: usize) -> SessionConfig {
    SessionConfig {
        strategy,
        rounds,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        // sequential clients: a deterministic push/pull order makes the
        // accuracy curves comparable bit-for-bit across backends
        parallel_clients: false,
        ..Default::default()
    }
}

/// Run one session on `tiny(seed)` against the given store (None = the
/// builder's default in-process server).
fn run_with(
    store: Option<Arc<dyn EmbeddingStore>>,
    strategy: Strategy,
    rounds: usize,
    seed: u64,
) -> SessionMetrics {
    let g = tiny(seed);
    let mut b = SessionBuilder::new(cfg(strategy, rounds));
    if let Some(s) = store {
        b = b.store(s);
    }
    b.build(&g, ref_engine()).unwrap().run().unwrap()
}

/// Like [`run_with`], with the async pipeline forced on or off.
fn run_with_pipeline(
    store: Option<Arc<dyn EmbeddingStore>>,
    strategy: Strategy,
    rounds: usize,
    seed: u64,
    pipeline: bool,
) -> SessionMetrics {
    let g = tiny(seed);
    let mut c = cfg(strategy, rounds);
    c.pipeline = pipeline;
    let mut b = SessionBuilder::new(c);
    if let Some(s) = store {
        b = b.store(s);
    }
    b.build(&g, ref_engine()).unwrap().run().unwrap()
}

fn assert_same_curve(a: &SessionMetrics, b: &SessionMetrics) {
    assert_eq!(
        a.accuracies(),
        b.accuracies(),
        "accuracy curves diverged between store backends"
    );
    assert_eq!(a.server_embeddings, b.server_embeddings);
    let va: Vec<f64> = a.rounds.iter().map(|r| r.val_loss).collect();
    let vb: Vec<f64> = b.rounds.iter().map(|r| r.val_loss).collect();
    assert_eq!(va, vb, "validation losses diverged between store backends");
}

// ---------------------------------------------------------------------------
// wire-protocol edges over loopback
// ---------------------------------------------------------------------------

fn daemon(hidden: usize) -> (EmbServerDaemon, Arc<EmbeddingServer>) {
    let server = Arc::new(EmbeddingServer::new(N_LAYERS, hidden, NetConfig::default()));
    let d = EmbServerDaemon::start(
        Arc::clone(&server) as Arc<dyn EmbeddingStore>,
        "127.0.0.1:0",
    )
    .unwrap();
    (d, server)
}

#[test]
fn wire_empty_push_pull_stats() {
    let (d, _server) = daemon(4);
    let mut c = RemoteEmbClient::connect(d.addr, N_LAYERS, 4).unwrap();
    // empty payloads are legal frames, not protocol errors
    let rec = c.push(&[], &[Vec::new(), Vec::new()]).unwrap();
    assert_eq!(rec.rows, 0);
    let (got, rec) = c.pull(&[]).unwrap();
    assert_eq!(rec.rows, 0);
    assert_eq!(got.len(), N_LAYERS);
    assert!(got.iter().all(|l| l.is_empty()));
    let s = c.stats().unwrap();
    assert_eq!((s.nodes, s.rows), (0, 0));
    // and the connection still serves real traffic afterwards
    c.push(&[7], &[vec![1.0; 4], vec![2.0; 4]]).unwrap();
    let s = c.stats().unwrap();
    assert_eq!((s.nodes, s.rows, s.failovers, s.epoch), (1, 2, 0, 0));
    d.shutdown();
}

#[test]
fn wire_batches_larger_than_push_batch() {
    // one frame far beyond the engine's push_batch (8): the protocol is
    // framed by explicit lengths, not by geometry
    let (d, server) = daemon(4);
    let mut c = RemoteEmbClient::connect(d.addr, N_LAYERS, 4).unwrap();
    let nodes: Vec<u32> = (0..10_000).collect();
    let rows: Vec<f32> = (0..nodes.len() * 4).map(|i| i as f32 * 0.5).collect();
    c.push(&nodes, &[rows.clone(), rows.clone()]).unwrap();
    let (got, _) = c.pull(&nodes).unwrap();
    assert_eq!(got[0], rows);
    assert_eq!(got[1], rows);
    assert_eq!(server.stored_nodes(), 10_000);
    d.shutdown();
}

// ---------------------------------------------------------------------------
// session-level backend parity (the acceptance criteria)
// ---------------------------------------------------------------------------

#[test]
fn tcp_store_session_matches_in_process() {
    let (d, _server) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let in_proc = run_with(None, Strategy::opp(), 4, 111);
    let over_tcp = run_with(Some(Arc::new(tcp)), Strategy::opp(), 4, 111);
    assert_same_curve(&in_proc, &over_tcp);
    assert!(over_tcp.store_backend.starts_with("tcp("));
    assert_eq!(in_proc.store_backend, "in-process");
    // OPP exercises both the prefetch pull and the on-demand path, so
    // both curves must have seen real communication
    assert!(over_tcp.server_embeddings > 0);
    d.shutdown();
}

#[test]
fn sharded_store_session_matches_in_process() {
    let sharded = ShardedStore::in_process(4, N_LAYERS, HIDDEN, NetConfig::default());
    let in_proc = run_with(None, Strategy::opp(), 4, 113);
    let over_shards = run_with(Some(Arc::new(sharded)), Strategy::opp(), 4, 113);
    assert_same_curve(&in_proc, &over_shards);
    assert!(over_shards.store_backend.starts_with("sharded(4 shards"));
}

#[test]
fn replicated_store_session_matches_in_process() {
    // R=1: every row lives on two backends; replication must be
    // invisible to the training loop (values, occupancy, curve)
    let replicated =
        ShardedStore::in_process_replicated(4, 1, N_LAYERS, HIDDEN, NetConfig::default()).unwrap();
    let in_proc = run_with(None, Strategy::opp(), 4, 123);
    let over_replicas = run_with(Some(Arc::new(replicated)), Strategy::opp(), 4, 123);
    assert_same_curve(&in_proc, &over_replicas);
    assert!(
        over_replicas.store_backend.contains("1 replica"),
        "{}",
        over_replicas.store_backend
    );
    // a fault-free replicated run absorbs no failovers
    assert_eq!(over_replicas.total_failovers(), 0);
}

#[test]
fn sharded_tcp_daemons_session_matches_in_process() {
    // four separate daemons, each fronting its own slab — the full
    // "multiple remote stores" deployment, hash-partitioned by the client
    let daemons: Vec<(EmbServerDaemon, Arc<EmbeddingServer>)> =
        (0..4).map(|_| daemon(HIDDEN)).collect();
    let backends: Vec<Arc<dyn EmbeddingStore>> = daemons
        .iter()
        .map(|(d, _)| {
            Arc::new(TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN).unwrap())
                as Arc<dyn EmbeddingStore>
        })
        .collect();
    let sharded = ShardedStore::new(backends).unwrap();
    let in_proc = run_with(None, Strategy::e(), 3, 117);
    let federated = run_with(Some(Arc::new(sharded)), Strategy::e(), 3, 117);
    assert_same_curve(&in_proc, &federated);
    // every daemon ended up owning a non-trivial share of the embeddings
    let total: usize = daemons.iter().map(|(_, s)| s.stored_nodes()).sum();
    assert_eq!(total, in_proc.server_embeddings);
    for (_, s) in &daemons {
        assert!(s.stored_nodes() > 0, "a shard owned no embeddings");
    }
    for (d, _) in daemons {
        d.shutdown();
    }
}

// ---------------------------------------------------------------------------
// against a real spawned `optimes serve` process
// ---------------------------------------------------------------------------

/// Kills the child even when an assertion fails mid-test.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn session_through_spawned_serve_process_matches_in_process() {
    use std::io::BufRead;
    let exe = env!("CARGO_BIN_EXE_optimes");
    let mut child = ChildGuard(
        std::process::Command::new(exe)
            .args([
                "serve",
                "--port",
                "0",
                "--layers",
                &N_LAYERS.to_string(),
                "--hidden",
                &HIDDEN.to_string(),
            ])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn optimes serve"),
    );
    let stdout = child.0.stdout.take().expect("child stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = None;
    for _ in 0..20 {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(pos) = line.find("listening on ") {
            let rest = &line[pos + "listening on ".len()..];
            addr = rest.split_whitespace().next().map(|s| s.to_string());
            break;
        }
    }
    let addr = addr.expect("serve process never reported its bound address");
    let tcp = TcpEmbeddingStore::connect(addr, N_LAYERS, HIDDEN).unwrap();
    let in_proc = run_with(None, Strategy::e(), 3, 119);
    let remote = run_with(Some(Arc::new(tcp)), Strategy::e(), 3, 119);
    assert_same_curve(&in_proc, &remote);
}

// ---------------------------------------------------------------------------
// async-pipeline parity: --pipeline on|off must be bit-identical on every
// backend for a fixed seed (overlap changes wall time, never results)
// ---------------------------------------------------------------------------

#[test]
fn pipeline_parity_in_process() {
    let off = run_with_pipeline(None, Strategy::opp(), 4, 211, false);
    let on = run_with_pipeline(None, Strategy::opp(), 4, 211, true);
    assert_same_curve(&off, &on);
    assert!(on.pipelined && !off.pipelined);
    let ov = on.overlap_stats();
    assert!(ov.pipelined, "pipelined session consumed no tickets");
    assert!(ov.push_wall > 0.0, "no measured push pipeline wall");
    assert_eq!(off.overlap_stats(), Default::default());
}

#[test]
fn pipeline_parity_tcp() {
    // fresh daemon per session: both runs must start on an empty store
    let (d_off, _s) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d_off.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let off = run_with_pipeline(Some(Arc::new(tcp)), Strategy::opp(), 4, 213, false);
    d_off.shutdown();

    let (d_on, _s) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d_on.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let on = run_with_pipeline(Some(Arc::new(tcp)), Strategy::opp(), 4, 213, true);
    assert_same_curve(&off, &on);
    let ov = on.overlap_stats();
    assert!(ov.pipelined);
    assert!(ov.push_wall > 0.0);
    assert!(ov.queue_peak >= 1);
    d_on.shutdown();
}

#[test]
fn pipeline_parity_4shard() {
    let mk = || -> Arc<dyn EmbeddingStore> {
        Arc::new(ShardedStore::in_process(4, N_LAYERS, HIDDEN, NetConfig::default()))
    };
    let off = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 217, false);
    let on = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 217, true);
    assert_same_curve(&off, &on);
    assert!(on.overlap_stats().pipelined);
}

#[test]
fn pipeline_parity_replicated_4shard() {
    let mk = || -> Arc<dyn EmbeddingStore> {
        Arc::new(
            ShardedStore::in_process_replicated(4, 1, N_LAYERS, HIDDEN, NetConfig::default())
                .unwrap(),
        )
    };
    let off = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 223, false);
    let on = run_with_pipeline(Some(mk()), Strategy::opp(), 4, 223, true);
    assert_same_curve(&off, &on);
    assert!(on.overlap_stats().pipelined);
}

#[test]
fn pipeline_overlap_is_real_under_throttled_store() {
    // sleep out the netsim cost model so store RPCs consume real wall
    // time: the pipelined session must measurably hide push/pull work
    // under training + aggregation while producing identical results
    let slow = NetConfig {
        latency: 0.02,
        ..NetConfig::default()
    };
    let mk = || -> Arc<dyn EmbeddingStore> {
        Arc::new(ThrottledStore::new(Arc::new(EmbeddingServer::new(N_LAYERS, HIDDEN, slow))))
    };
    let off = run_with_pipeline(Some(mk()), Strategy::o(), 3, 219, false);
    let on = run_with_pipeline(Some(mk()), Strategy::o(), 3, 219, true);
    assert_same_curve(&off, &on);
    let ov = on.overlap_stats();
    assert!(ov.pipelined);
    assert!(ov.overlap_saved > 0.0, "pipeline hid no real work: {ov:?}");
    // the real measurement and the virtual model agree that work was
    // hidden (they need not agree on the amount)
    let virtual_hidden: f64 = on.rounds.iter().map(|r| r.mean_phases.push_hidden).sum();
    assert!(virtual_hidden > 0.0);
}

#[test]
fn tcp_store_works_with_parallel_clients() {
    // parallel clients share the pooled TCP store: results must still be
    // structurally sound (bit-parity is only guaranteed sequentially)
    let (d, _server) = daemon(HIDDEN);
    let tcp = TcpEmbeddingStore::connect(d.addr.to_string(), N_LAYERS, HIDDEN).unwrap();
    let g = tiny(121);
    let mut c = cfg(Strategy::o(), 3);
    c.parallel_clients = true;
    let m = SessionBuilder::new(c)
        .store(Arc::new(tcp))
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(m.rounds.len(), 3);
    assert!(m.rounds.iter().all(|r| r.accuracy.is_finite()));
    assert!(m.server_embeddings > 0);
    d.shutdown();
}
