//! Out-of-core graph plane acceptance suite (DESIGN.md §13):
//!
//! * corruption injection — a byte flipped at every header field and
//!   every section boundary of a `GraphFile` must fail the load with a
//!   *named* error (magic / version / endian / header checksum / section
//!   checksum / truncation), never a panic, on both backends;
//! * round-trip properties — hand-built graphs with empty-neighbour and
//!   max-degree vertices survive write → load bit-exactly on `ram` and
//!   `mmap`;
//! * streaming partitioners — `ldg` is deterministic per seed, balanced
//!   within the `metis_lite` cap, beats the hash baseline, and lands
//!   within tolerance of `metis_lite`'s edge cut;
//! * backend parity — a federated session produces the exact same
//!   accuracy curve whether the graph's bulk arrays live on the heap or
//!   in mapped `GraphFile` pages, pipeline on or off (the CI
//!   `graph-backend` job additionally reruns `store_parity` and
//!   `federation_e2e` under `OPTIMES_GRAPH_BACKEND=ram|mmap`);
//! * bounded RSS — the `#[ignore]`d smoke builds a multi-million-vertex
//!   graph with `generate_to_file` and trains one round off the mapped
//!   file, asserting peak RSS (`VmHWM`) stays under a fixed budget.

use std::path::PathBuf;
use std::sync::Arc;

use optimes::coordinator::{SessionBuilder, SessionConfig, SessionMetrics, Strategy};
use optimes::graph::generate::{generate, generate_to_file, GenParams};
use optimes::graph::partition::metis_lite;
use optimes::graph::{Csr, Graph, PartitionerKind};
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};
use optimes::storage::{
    hash_partition_n, ldg_partition_file, ldg_partition_graph, load_graph_file, write_graph_file,
    GraphBackend, GraphStore,
};
use optimes::util::proptest::{check, Gen};
use optimes::util::rng::Rng;
use optimes::{prop_assert, prop_assert_eq};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("optimes-gb-{}-{name}", std::process::id()))
}

fn tiny_graph(seed: u64) -> Graph {
    generate(&GenParams {
        n: 600,
        avg_degree: 10.0,
        communities: 4,
        classes: 4,
        feat_dim: 32,
        homophily: 0.85,
        hub_alpha: 1.5,
        signal: 0.65,
        community_bias: 0.4,
        train_frac: 0.5,
        test_frac: 0.25,
        seed,
    })
}

fn assert_graphs_bit_equal(a: &Graph, b: &Graph) {
    assert_eq!(a.n, b.n);
    assert_eq!(a.feat_dim, b.feat_dim);
    assert_eq!(a.classes, b.classes);
    assert_eq!(a.out.offsets, b.out.offsets);
    assert_eq!(a.out.targets, b.out.targets);
    assert_eq!(a.inc.offsets, b.inc.offsets);
    assert_eq!(a.inc.targets, b.inc.targets);
    assert_eq!(a.features, b.features);
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.train_nodes, b.train_nodes);
    assert_eq!(a.test_nodes, b.test_nodes);
}

// ---------------------------------------------------------------------------
// corruption injection
// ---------------------------------------------------------------------------

#[test]
fn corruption_names_the_failure_at_every_boundary() {
    let g = tiny_graph(1);
    let path = tmp("corrupt.graph");
    let info = write_graph_file(&path, &g).unwrap();
    let pristine = std::fs::read(&path).unwrap();

    // (byte offset to flip, substring the named error must contain)
    let mut probes: Vec<(u64, &str)> = vec![
        (0, "bad magic"),
        (8, "unsupported version"),
        (12, "endian marker"),
        (16, "header checksum"),  // n
        (24, "header checksum"),  // m
        (40, "header checksum"),  // train_count
        (56, "header checksum"),  // first section-table entry
        (240, "header checksum"), // last section-table entry
        (248, "header checksum"), // the stored meta checksum itself
    ];
    for sec in info.sections.iter() {
        assert!(sec.byte_len > 0, "test graph must populate every section");
        probes.push((sec.offset, "checksum mismatch in section"));
        probes.push((sec.offset + sec.byte_len - 1, "checksum mismatch in section"));
    }
    for (off, needle) in probes {
        let mut bytes = pristine.clone();
        bytes[off as usize] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        for backend in [GraphBackend::Ram, GraphBackend::Mmap] {
            let err = load_graph_file(&path, backend)
                .expect_err("corrupted file must not load")
                .to_string();
            assert!(
                err.contains(needle),
                "flip at byte {off} ({backend:?}): expected {needle:?} in error, got: {err}"
            );
        }
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn truncation_fails_with_named_errors() {
    let g = tiny_graph(2);
    let path = tmp("trunc.graph");
    write_graph_file(&path, &g).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // shorter than the fixed header
    std::fs::write(&path, &bytes[..100]).unwrap();
    let err = load_graph_file(&path, GraphBackend::Ram).unwrap_err().to_string();
    assert!(err.contains("truncated header"), "{err}");

    // one byte short of the recorded section layout
    std::fs::write(&path, &bytes[..bytes.len() - 1]).unwrap();
    for backend in [GraphBackend::Ram, GraphBackend::Mmap] {
        let err = load_graph_file(&path, backend).unwrap_err().to_string();
        assert!(err.contains("truncated"), "({backend:?}): {err}");
    }

    // trailing garbage is caught too
    let mut long = bytes.clone();
    long.extend_from_slice(&[0u8; 17]);
    std::fs::write(&path, &long).unwrap();
    let err = load_graph_file(&path, GraphBackend::Ram).unwrap_err().to_string();
    assert!(err.contains("trailing"), "{err}");

    std::fs::remove_file(&path).unwrap();
}

// ---------------------------------------------------------------------------
// round-trip properties
// ---------------------------------------------------------------------------

#[test]
fn prop_roundtrip_bit_exact_with_degenerate_vertices() {
    let path = tmp("prop-roundtrip.graph");
    check(
        "graphfile-roundtrip",
        12,
        |g: &mut Gen| {
            let n = 20 + g.int_scaled(0, 300);
            (n, g.int(0, 1_000_000) as u64, g.bool())
        },
        |(n, seed, empty_split)| {
            // Hand-built topology with the format's edge cases: vertex 0
            // is a hub wired to/from every non-isolated vertex (max
            // degree), vertex n-1 is fully isolated (empty neighbour
            // lists in both directions).
            let n = *n;
            let mut rng = Rng::new(*seed, 0x77);
            let mut edges: Vec<(u32, u32)> = Vec::new();
            for t in 1..(n as u32 - 1) {
                edges.push((0, t));
                edges.push((t, 0));
            }
            for _ in 0..n * 2 {
                let s = 1 + rng.below(n - 2) as u32;
                let d = 1 + rng.below(n - 2) as u32;
                edges.push((s, d));
            }
            let out = Csr::from_edges(n, &edges);
            let inc = out.reversed();
            let feat_dim = 4;
            let features: Vec<f32> = (0..n * feat_dim).map(|_| rng.normal() as f32).collect();
            let labels: Vec<u16> = (0..n).map(|_| rng.below(4) as u16).collect();
            let (train_nodes, test_nodes): (Vec<u32>, Vec<u32>) = if *empty_split {
                (Vec::new(), Vec::new())
            } else {
                ((0..n as u32 / 2).collect(), (n as u32 / 2..n as u32).collect())
            };
            let g = Graph {
                n,
                out,
                inc,
                feat_dim,
                classes: 4,
                features: features.into(),
                labels: labels.into(),
                train_nodes,
                test_nodes,
            };
            g.validate().expect("hand-built graph must be valid");
            prop_assert_eq!(g.out.degree(n as u32 - 1), 0);
            prop_assert_eq!(g.inc.degree(n as u32 - 1), 0);
            prop_assert_eq!(g.out.degree(0), n - 2);

            let info = write_graph_file(&path, &g).expect("write");
            prop_assert_eq!(info.m, g.out.m());
            for backend in [GraphBackend::Ram, GraphBackend::Mmap] {
                let h = load_graph_file(&path, backend).expect("load");
                prop_assert!(
                    h.is_mapped() == (backend == GraphBackend::Mmap),
                    "backend {backend:?} mapped flag wrong"
                );
                assert_graphs_bit_equal(&g, &h);
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn streamed_generator_matches_in_memory_through_both_backends() {
    let p = GenParams {
        n: 700,
        avg_degree: 9.0,
        community_bias: 0.4,
        ..GenParams::default()
    };
    let g = generate(&p);
    let path = tmp("gen-stream.graph");
    generate_to_file(&p, &path).unwrap();
    for backend in [GraphBackend::Ram, GraphBackend::Mmap] {
        let h = GraphStore::open(&path, backend).unwrap();
        assert_graphs_bit_equal(&g, &h);
    }
    std::fs::remove_file(&path).unwrap();
}

// ---------------------------------------------------------------------------
// streaming partitioners
// ---------------------------------------------------------------------------

#[test]
fn ldg_deterministic_balanced_and_competitive() {
    let g = generate(&GenParams {
        n: 1500,
        ..GenParams::default()
    });
    for k in [2, 4] {
        let a = ldg_partition_graph(&g, k, 7).unwrap();
        let b = ldg_partition_graph(&g, k, 7).unwrap();
        assert_eq!(a.assign, b.assign, "ldg must be deterministic per seed");
        assert!(a.imbalance() < 1.15, "imbalance {}", a.imbalance());
        assert!(a.sizes().iter().all(|&s| s > 0));

        let m = metis_lite(&g, k, 7);
        let h = hash_partition_n(g.n, k, 7);
        let (cut_ldg, cut_metis, cut_hash) =
            (a.cut_fraction(&g), m.cut_fraction(&g), h.cut_fraction(&g));
        assert!(
            cut_ldg <= cut_metis + 0.35,
            "k={k}: ldg cut {cut_ldg:.3} too far above metis_lite {cut_metis:.3}"
        );
        assert!(
            cut_ldg < cut_hash,
            "k={k}: ldg cut {cut_ldg:.3} must beat random {cut_hash:.3}"
        );
    }
}

#[test]
fn ldg_off_the_file_matches_the_in_ram_pass() {
    let g = tiny_graph(3);
    let path = tmp("ldg-file.graph");
    write_graph_file(&path, &g).unwrap();
    let from_graph = ldg_partition_graph(&g, 4, 9).unwrap();
    let from_file = ldg_partition_file(&path, 4, 9).unwrap();
    assert_eq!(from_graph.assign, from_file.assign);
    std::fs::remove_file(&path).unwrap();
}

// ---------------------------------------------------------------------------
// backend parity: identical accuracy curves
// ---------------------------------------------------------------------------

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: 16,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn run_session(g: &Graph, pipeline: bool, partitioner: PartitionerKind) -> SessionMetrics {
    let cfg = SessionConfig {
        strategy: Strategy::opp(),
        rounds: 3,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        // sequential clients: deterministic push/pull order keeps the
        // curves comparable bit-for-bit across backends
        parallel_clients: false,
        pipeline,
        partitioner,
        ..Default::default()
    };
    SessionBuilder::new(cfg)
        .build(g, ref_engine())
        .unwrap()
        .run()
        .unwrap()
}

fn assert_same_curve(a: &SessionMetrics, b: &SessionMetrics) {
    assert_eq!(
        a.accuracies(),
        b.accuracies(),
        "accuracy curves diverged between graph backends"
    );
    let va: Vec<f64> = a.rounds.iter().map(|r| r.val_loss).collect();
    let vb: Vec<f64> = b.rounds.iter().map(|r| r.val_loss).collect();
    assert_eq!(va, vb, "validation losses diverged between graph backends");
}

#[test]
fn session_curves_bit_identical_ram_vs_mmap() {
    let g_ram = tiny_graph(11);
    let g_mmap = GraphStore::adopt(g_ram.clone(), GraphBackend::Mmap).unwrap();
    assert!(g_mmap.is_mapped() && !g_ram.is_mapped());
    assert_graphs_bit_equal(&g_ram, &g_mmap);
    for pipeline in [false, true] {
        let a = run_session(&g_ram, pipeline, PartitionerKind::Metis);
        let b = run_session(&g_mmap, pipeline, PartitionerKind::Metis);
        assert_same_curve(&a, &b);
    }
}

#[test]
fn session_curves_bit_identical_under_streaming_partitioner() {
    let g_ram = tiny_graph(12);
    let g_mmap = GraphStore::adopt(g_ram.clone(), GraphBackend::Mmap).unwrap();
    let a = run_session(&g_ram, true, PartitionerKind::Ldg);
    let b = run_session(&g_mmap, true, PartitionerKind::Ldg);
    assert_same_curve(&a, &b);
}

// ---------------------------------------------------------------------------
// bounded-RSS smoke
// ---------------------------------------------------------------------------

/// Peak resident set (`VmHWM`) in MB from `/proc/self/status`.
fn peak_rss_mb() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb / 1024);
        }
    }
    None
}

/// The out-of-core acceptance smoke: build a multi-million-vertex graph
/// on disk (`OPTIMES_RSS_SMOKE_N`, default 10M — a graph whose features
/// alone exceed 1 GB) and train one federated round off the mapped
/// file, asserting peak RSS stays under `OPTIMES_RSS_BUDGET_MB`
/// (default 3000). Run explicitly: the CI `graph-backend` job's mmap
/// leg executes it in release mode.
#[test]
#[ignore = "multi-GB out-of-core smoke; run with --ignored (CI graph-backend job, mmap leg)"]
fn bounded_rss_build_and_train_ten_million_vertices() {
    let env_usize = |k: &str, d: usize| {
        std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    let n = env_usize("OPTIMES_RSS_SMOKE_N", 10_000_000);
    let budget_mb = env_usize("OPTIMES_RSS_BUDGET_MB", 3000) as u64;
    let path = tmp("rss-smoke.graph");

    let gen = GenParams {
        n,
        avg_degree: 6.0,
        communities: 16,
        classes: 4,
        feat_dim: 32,
        ..GenParams::default()
    };
    let info = generate_to_file(&gen, &path).expect("streamed build-graph");
    assert_eq!(info.n, n);
    let after_build = peak_rss_mb().expect("the RSS smoke needs linux /proc");
    assert!(
        after_build < budget_mb,
        "build-graph peak RSS {after_build} MB >= budget {budget_mb} MB (n={n})"
    );

    let g = GraphStore::open(&path, GraphBackend::Mmap).expect("open mapped");
    assert!(g.is_mapped());
    let cfg = SessionConfig {
        strategy: Strategy::d(),
        clients: 2,
        rounds: 1,
        epochs: 1,
        epoch_batches: 2,
        eval_batches: 1,
        parallel_clients: false,
        partitioner: PartitionerKind::Ldg,
        ..Default::default()
    };
    let m = SessionBuilder::new(cfg)
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(m.rounds.len(), 1);
    let peak = peak_rss_mb().expect("the RSS smoke needs linux /proc");
    std::fs::remove_file(&path).unwrap();
    assert!(
        peak < budget_mb,
        "peak RSS {peak} MB >= budget {budget_mb} MB after one round (n={n})"
    );
}
