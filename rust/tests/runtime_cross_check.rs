//! Integration: the PJRT engine (AOT HLO with Pallas kernels) must agree
//! with the pure-Rust RefEngine on identical weights and batches.
//!
//! Requires `make artifacts`; tests skip gracefully when artifacts are
//! missing so `cargo test` stays runnable pre-build.

use optimes::runtime::{
    Batch, Manifest, ModelKind, ModelState, PjrtEngine, RefEngine, StepEngine,
};
use optimes::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match Manifest::load(dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping pjrt cross-check: {e}");
            None
        }
    }
}

/// Random batch with the constant tree adjacency for a given geometry.
fn rand_batch(
    geom: &optimes::runtime::ModelGeom,
    depth: usize,
    width: usize,
    seed: u64,
) -> Batch {
    let mut rng = Rng::new(seed, 0x7E57);
    let k = geom.fanout;
    let mut adj = Vec::new();
    let mut msk = Vec::new();
    let mut s = width;
    let mut sizes = vec![width];
    for _ in 0..depth {
        adj.push((0..s * k).map(|e| (s + e) as i32).collect::<Vec<i32>>());
        msk.push(
            (0..s * k)
                .map(|_| if rng.chance(0.75) { 1.0 } else { 0.0 })
                .collect(),
        );
        s += s * k;
        sizes.push(s);
    }
    let deepest = *sizes.last().unwrap();
    let x = (0..deepest * geom.feat)
        .map(|_| rng.normal() as f32 * 0.5)
        .collect();
    let n_sub = if depth == geom.layers {
        geom.layers - 1
    } else {
        depth - 1
    };
    let rmask: Vec<Vec<f32>> = (1..=n_sub)
        .map(|l| {
            let lvl = depth - l;
            (0..sizes[lvl])
                .map(|_| if rng.chance(0.25) { 1.0 } else { 0.0 })
                .collect()
        })
        .collect();
    let cache: Vec<Vec<f32>> = (1..=n_sub)
        .map(|l| {
            let lvl = depth - l;
            (0..sizes[lvl] * geom.hidden)
                .map(|_| rng.normal() as f32 * 0.3)
                .collect()
        })
        .collect();
    let labels = (0..width).map(|_| rng.below(geom.classes) as i32).collect();
    let lmask = (0..width)
        .map(|i| if i + 2 < width { 1.0 } else { 0.0 })
        .collect();
    Batch {
        depth,
        width,
        x,
        adj: adj.into(),
        msk,
        rmask,
        cache,
        labels,
        lmask,
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn cross_check(model: ModelKind) {
    let Some(m) = manifest() else { return };
    let pjrt = PjrtEngine::start(&m, model, 5).expect("pjrt engine");
    let geom = *pjrt.geom();
    let reff = RefEngine::new(geom);

    // --- eval agreement
    let state = ModelState::init(&geom, 42);
    let batch = rand_batch(&geom, geom.layers, geom.batch, 1);
    let ep = pjrt.evaluate(&state, &batch).unwrap();
    let er = reff.evaluate(&state, &batch).unwrap();
    assert!(
        (ep.loss - er.loss).abs() < 1e-3,
        "{model:?} eval loss pjrt={} ref={}",
        ep.loss,
        er.loss
    );
    assert_eq!(ep.correct, er.correct, "{model:?} eval correct");
    assert_eq!(ep.total, er.total);

    // --- train agreement over several steps
    let mut sp = state.clone();
    let mut sr = state.clone();
    for step in 0..3 {
        let b = rand_batch(&geom, geom.layers, geom.batch, 10 + step);
        let tp = pjrt.train_step(&mut sp, &b, 0.01).unwrap();
        let tr = reff.train_step(&mut sr, &b, 0.01).unwrap();
        assert!(
            (tp.loss - tr.loss).abs() < 2e-3,
            "{model:?} step {step} loss pjrt={} ref={}",
            tp.loss,
            tr.loss
        );
        for (i, (p, r)) in sp.params.iter().zip(&sr.params).enumerate() {
            let d = max_abs_diff(p, r);
            assert!(d < 5e-3, "{model:?} step {step} param {i} drift {d}");
        }
    }

    // --- embed agreement
    let eb = rand_batch(&geom, geom.layers - 1, geom.push_batch, 77);
    let hp = pjrt.embed(&state, &eb).unwrap();
    let hr = reff.embed(&state, &eb).unwrap();
    assert_eq!(hp.len(), hr.len());
    for (l, (a, b)) in hp.iter().zip(&hr).enumerate() {
        let d = max_abs_diff(a, b);
        assert!(d < 1e-3, "{model:?} embed h{} drift {d}", l + 1);
    }
}

#[test]
fn pjrt_matches_ref_gc() {
    cross_check(ModelKind::Gc);
}

#[test]
fn pjrt_matches_ref_sage() {
    cross_check(ModelKind::Sage);
}

#[test]
fn smoke_artifact() {
    let Some(m) = manifest() else { return };
    let v = optimes::runtime::pjrt::run_smoke(&m).unwrap();
    assert_eq!(v, vec![5.0, 5.0, 9.0, 9.0]);
}
