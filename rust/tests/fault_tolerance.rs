//! Deterministic chaos suite for the replicated embedding plane
//! (DESIGN.md §10): a federated session must ride out injected store
//! failures — single RPC errors, latency spikes, and a *full shard
//! blackout mid-training* — with an accuracy curve that is bit-identical
//! to the fault-free run, as long as the shard map keeps at least one
//! replica (`--shards 4 --replicas 1`). Without replicas the run must
//! fail loudly, never corrupt silently.
//!
//! Every scenario here forces the async pipeline both off and on
//! explicitly (`SessionConfig.pipeline`), independent of the
//! environment. The CI `OPTIMES_PIPELINE=on|off` matrix re-runs this
//! file alongside `store_parity` — the latter is what actually reads
//! the env default — so the matrix legs differ through that suite, not
//! this one. Sessions use sequential clients, which is what makes
//! curves comparable bit-for-bit (the same guarantee
//! `tests/store_parity.rs` leans on).
//!
//! Also here: the rebalance-away/rejoin protocol under training load,
//! snapshot-based shard restart, and the interleaved
//! push/pull/rebalance hammer (the sharded/replicated sibling of
//! `embedding_server.rs`'s slab hammer).

use std::sync::Arc;

use optimes::coordinator::{
    EmbeddingStore, Fault, FaultHandle, FaultStore, NetConfig, SessionBuilder, SessionConfig,
    SessionMetrics, ShardMap, ShardedStore, SnapshotStore, Strategy,
};
use optimes::graph::datasets::tiny;
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};

const HIDDEN: usize = 16;
const N_LAYERS: usize = 2; // layers - 1
const SHARDS: usize = 4;
const ROUNDS: usize = 6;

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: HIDDEN,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn cfg(pipeline: bool) -> SessionConfig {
    SessionConfig {
        strategy: Strategy::e(),
        rounds: ROUNDS,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        // sequential clients: deterministic push/pull order makes the
        // accuracy curves comparable bit-for-bit across runs
        parallel_clients: false,
        pipeline,
        ..Default::default()
    }
}

/// In-process slab backends plus a FaultStore wrapper per shard, with
/// the handles to script failures mid-run.
fn faulted_backends(shards: usize) -> (Vec<Arc<dyn EmbeddingStore>>, Vec<FaultHandle>) {
    let mut backends: Vec<Arc<dyn EmbeddingStore>> = Vec::new();
    let mut handles = Vec::new();
    for i in 0..shards {
        let inner: Arc<dyn EmbeddingStore> = Arc::new(
            optimes::coordinator::EmbeddingServer::new(N_LAYERS, HIDDEN, NetConfig::default()),
        );
        let faulted = FaultStore::new(inner, format!("shard{i}"), Vec::new());
        handles.push(faulted.handle());
        backends.push(Arc::new(faulted));
    }
    (backends, handles)
}

/// Run a full session against `store` on `tiny(seed)`, invoking `at_round`
/// with the round index before each round runs (the chaos hook). The
/// store is wrapped per `OPTIMES_WIRE_CODEC` — the CI `wire-codec` job
/// reruns this suite as a `raw|int8` matrix, and every chaos scenario
/// must hold under a codec exactly as it holds raw (baseline and chaos
/// runs are wrapped identically; DESIGN.md §11).
fn run_with_hook(
    store: Arc<dyn EmbeddingStore>,
    pipeline: bool,
    seed: u64,
    mut at_round: impl FnMut(usize),
) -> SessionMetrics {
    let store = optimes::wire::wrap_from_env(store, NetConfig::default());
    let g = tiny(seed);
    let mut session = SessionBuilder::new(cfg(pipeline))
        .store(store)
        .build(&g, ref_engine())
        .unwrap();
    session.pretrain().unwrap();
    while session.completed_rounds() < session.planned_rounds() {
        at_round(session.completed_rounds());
        session.run_round().unwrap();
    }
    session.finish()
}

/// Fault-free baseline on a replicated store.
fn baseline(pipeline: bool, seed: u64) -> SessionMetrics {
    let store =
        ShardedStore::in_process_replicated(SHARDS, 1, N_LAYERS, HIDDEN, NetConfig::default())
            .unwrap();
    run_with_hook(Arc::new(store), pipeline, seed, |_| {})
}

fn assert_same_curve(a: &SessionMetrics, b: &SessionMetrics) {
    assert_eq!(
        a.accuracies(),
        b.accuracies(),
        "accuracy curves diverged under injected faults"
    );
    let va: Vec<f64> = a.rounds.iter().map(|r| r.val_loss).collect();
    let vb: Vec<f64> = b.rounds.iter().map(|r| r.val_loss).collect();
    assert_eq!(va, vb, "validation losses diverged under injected faults");
    assert_eq!(a.server_embeddings, b.server_embeddings);
}

// ---------------------------------------------------------------------------
// the acceptance criterion: full shard blackout mid-training, R = 1
// ---------------------------------------------------------------------------

#[test]
fn shard_blackout_mid_training_matches_fault_free_curve() {
    const SEED: u64 = 311;
    const KILL_SHARD: usize = 1;
    const KILL_AT_ROUND: usize = 2;
    for pipeline in [false, true] {
        let base = baseline(pipeline, SEED);
        assert_eq!(base.total_failovers(), 0);

        let (backends, handles) = faulted_backends(SHARDS);
        let store = ShardedStore::replicated(backends, 1).unwrap();
        let chaos = run_with_hook(Arc::new(store), pipeline, SEED, |round| {
            if round == KILL_AT_ROUND {
                handles[KILL_SHARD].set_blackout(true);
            }
        });

        // the run completed all rounds with a bit-identical curve...
        assert_eq!(chaos.rounds.len(), ROUNDS);
        assert_same_curve(&base, &chaos);
        // ...while genuinely absorbing failures on the dead shard
        assert!(
            chaos.total_failovers() > 0,
            "pipeline={pipeline}: blackout absorbed no failovers"
        );
        assert!(handles[KILL_SHARD].injected() > 0, "blackout never fired");
        // failovers only start once the shard dies
        assert_eq!(chaos.rounds[KILL_AT_ROUND - 1].failovers, 0);
        assert!(chaos.rounds[ROUNDS - 1].failovers >= chaos.rounds[KILL_AT_ROUND].failovers);
    }
}

#[test]
fn single_rpc_error_is_invisible_with_replicas() {
    const SEED: u64 = 313;
    for pipeline in [false, true] {
        let base = baseline(pipeline, SEED);
        let (backends, handles) = faulted_backends(SHARDS);
        handles[2].add_fault(Fault::ErrOn(3));
        handles[0].add_fault(Fault::ErrEvery(7));
        let store = ShardedStore::replicated(backends, 1).unwrap();
        let chaos = run_with_hook(Arc::new(store), pipeline, SEED, |_| {});
        assert_same_curve(&base, &chaos);
        assert!(chaos.total_failovers() > 0);
    }
}

#[test]
fn latency_spikes_change_virtual_time_not_values() {
    use optimes::coordinator::metrics::RpcKind;
    const SEED: u64 = 317;
    // summed model-time of every store RPC the session issued — injected
    // delays are charged here (the virtual clock), not slept for real
    let rpc_time = |m: &SessionMetrics| -> f64 {
        [RpcKind::Pull, RpcKind::PullOnDemand, RpcKind::Push]
            .into_iter()
            .flat_map(|k| m.rpcs(k))
            .map(|r| r.time)
            .sum()
    };
    for pipeline in [false, true] {
        let base = baseline(pipeline, SEED);
        let (backends, handles) = faulted_backends(SHARDS);
        for h in &handles {
            h.add_fault(Fault::DelayEvery { every: 3, secs: 0.002 });
        }
        let store = ShardedStore::replicated(backends, 1).unwrap();
        let chaos = run_with_hook(Arc::new(store), pipeline, SEED, |_| {});
        assert_same_curve(&base, &chaos);
        // delays are not failures
        assert_eq!(chaos.total_failovers(), 0);
        // ...but they do show up in the modeled RPC time
        assert!(
            rpc_time(&chaos) > rpc_time(&base),
            "pipeline={pipeline}: injected delays never reached the virtual clock"
        );
    }
}

#[test]
fn blackout_without_replicas_fails_loudly_not_silently() {
    // R = 0: a dead shard has nowhere to fail over to. The session must
    // surface the injected error instead of training on zeros.
    let (backends, handles) = faulted_backends(SHARDS);
    handles[1].set_blackout(true);
    let store = ShardedStore::new(backends).unwrap();
    let g = tiny(331);
    let err = SessionBuilder::new(cfg(false))
        .store(optimes::wire::wrap_from_env(Arc::new(store), NetConfig::default()))
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .err()
        .expect("R=0 blackout must fail the run");
    let chain = format!("{err:#}");
    assert!(chain.contains("injected fault"), "unexpected error chain: {chain}");
}

// ---------------------------------------------------------------------------
// the wire-plane acceptance criterion: a lossless codec plane
// (raw + delta) stays bit-identical through a mid-training shard
// blackout at R = 1, pipeline on and off (DESIGN.md §11)
// ---------------------------------------------------------------------------

#[test]
fn raw_delta_blackout_matches_fault_free_curve() {
    const SEED: u64 = 347;
    const KILL_SHARD: usize = 2;
    const KILL_AT_ROUND: usize = 2;
    for pipeline in [false, true] {
        let base = baseline(pipeline, SEED);

        let (backends, handles) = faulted_backends(SHARDS);
        let sharded = ShardedStore::replicated(backends, 1).unwrap();
        let delta: Arc<dyn EmbeddingStore> = Arc::new(optimes::wire::DeltaStore::new(
            Arc::new(sharded) as Arc<dyn EmbeddingStore>,
            0.0,
        ));
        let chaos = run_with_hook(delta, pipeline, SEED, |round| {
            if round == KILL_AT_ROUND {
                handles[KILL_SHARD].set_blackout(true);
            }
        });

        // delta elides only bit-identical rows and the replicated plane
        // serves skipped rows through the blackout exactly like
        // re-pushed ones — the curve must match the fault-free raw run
        assert_eq!(chaos.rounds.len(), ROUNDS);
        assert_same_curve(&base, &chaos);
        assert!(
            chaos.total_failovers() > 0,
            "pipeline={pipeline}: delta blackout absorbed no failovers"
        );
        assert!(handles[KILL_SHARD].injected() > 0, "blackout never fired");
        // (wire_codec reads `raw+delta` unless the CI codec matrix adds
        // its own outer codec layer)
        if optimes::wire::spec_from_env().unwrap().is_plain() {
            assert_eq!(chaos.wire_codec, "raw+delta");
            assert!(chaos.wire_ratio() >= 1.0 - 1e-9);
        }
    }
}

// ---------------------------------------------------------------------------
// rebalance under load: route around a dead shard, then re-admit it
// ---------------------------------------------------------------------------

#[test]
fn rebalance_away_and_rejoin_preserves_curve() {
    const SEED: u64 = 337;
    const DEAD: usize = 2;
    for pipeline in [false, true] {
        let base = baseline(pipeline, SEED);

        let (backends, handles) = faulted_backends(SHARDS);
        let sharded = Arc::new(ShardedStore::replicated(backends, 1).unwrap());
        let router = Arc::clone(&sharded);
        let chaos = run_with_hook(sharded, pipeline, SEED, |round| {
            if round == 2 {
                // shard DEAD dies; route every bucket away from it (the
                // migration itself must fail over around the corpse)
                handles[DEAD].set_blackout(true);
                let away = router.map().excluding(DEAD).unwrap();
                let report = router.rebalance(away).unwrap();
                assert_eq!(report.epoch, 1);
                assert!(report.buckets_changed > 0);
                assert!(report.rows_copied > 0, "mid-training store had rows to move");
            }
            if round == 4 {
                // the shard restarts (its slab intact but stale); the
                // rejoin rebalance recopies every bucket it re-owns
                handles[DEAD].set_blackout(false);
                let back = ShardMap::uniform(SHARDS, 1).unwrap();
                let report = router.rebalance(back).unwrap();
                assert_eq!(report.epoch, 2);
                assert!(report.rows_copied > 0);
            }
        });

        assert_same_curve(&base, &chaos);
        assert_eq!(chaos.store_epoch, 2, "session never saw the final epoch");
        // after the rejoin the plane is whole again: the last rounds'
        // reads go to the re-admitted primary without failing over
        let last_round_failovers =
            chaos.rounds[ROUNDS - 1].failovers - chaos.rounds[ROUNDS - 2].failovers;
        assert_eq!(last_round_failovers, 0, "rejoined shard still failing over");
    }
}

// ---------------------------------------------------------------------------
// snapshot-based shard restart
// ---------------------------------------------------------------------------

#[test]
fn restarted_shard_rejoins_warm_from_snapshot() {
    // shard 3 runs behind a SnapshotStore; after "crashing", a fresh
    // slab is rebuilt from its dump and serves bit-identical rows.
    let mk_slab = || -> Arc<dyn EmbeddingStore> {
        Arc::new(optimes::coordinator::EmbeddingServer::new(N_LAYERS, HIDDEN, NetConfig::default()))
    };
    let snap = Arc::new(SnapshotStore::new(mk_slab()));
    let mut backends: Vec<Arc<dyn EmbeddingStore>> = (0..SHARDS - 1).map(|_| mk_slab()).collect();
    backends.push(Arc::clone(&snap) as Arc<dyn EmbeddingStore>);
    let store = ShardedStore::replicated(backends, 1).unwrap();

    let nodes: Vec<u32> = (0..300).collect();
    let layer: Vec<f32> = nodes
        .iter()
        .flat_map(|&n| (0..HIDDEN).map(move |j| n as f32 + j as f32 * 0.125))
        .collect();
    store.push(&nodes, &[layer.clone(), layer.clone()]).unwrap();
    assert!(snap.shadow_nodes() > 0, "shard 3 owned nothing");

    // crash: dump the shadow, restore into a brand-new empty slab
    let mut bytes = Vec::new();
    let dumped = snap.dump(&mut bytes).unwrap();
    assert_eq!(dumped, snap.shadow_nodes());
    let restarted = SnapshotStore::restore(&mut &bytes[..], mk_slab()).unwrap();
    assert_eq!(restarted.shadow_nodes(), dumped);

    // the restarted shard serves exactly what the original served
    let shard3_nodes: Vec<u32> = nodes
        .iter()
        .copied()
        .filter(|&n| store.map().owners_of(n).contains(&((SHARDS - 1) as u32)))
        .collect();
    assert!(!shard3_nodes.is_empty());
    let (a, _) = snap.pull(&shard3_nodes, false).unwrap();
    let (b, _) = restarted.pull(&shard3_nodes, false).unwrap();
    assert_eq!(a, b, "restored shard diverged from the original");
}

// ---------------------------------------------------------------------------
// soak: interleaved push/pull/rebalance hammer on a 4-shard R=1 store
// ---------------------------------------------------------------------------

#[test]
fn replicated_store_survives_push_pull_rebalance_hammer() {
    // Writers race on a SHARED node set with per-writer uniform rows;
    // readers assert every pulled row is internally consistent (all
    // `hidden` lanes agree — never torn, never lost) while a rebalancer
    // keeps migrating buckets between two maps under their feet. This is
    // the sharded/replicated sibling of the slab hammer in
    // `embedding_server.rs`.
    let h = 8;
    let store = Arc::new(
        ShardedStore::in_process_replicated(4, 1, 2, h, NetConfig::default()).unwrap(),
    );
    let nodes: Vec<u32> = (0..128).collect();
    // seed every row so readers never observe a not-yet-pushed zero row
    let seed_layer: Vec<f32> = vec![0.5; nodes.len() * h];
    store.push(&nodes, &[seed_layer.clone(), seed_layer]).unwrap();

    let mut handles = Vec::new();
    for w in 0..4u32 {
        let store = Arc::clone(&store);
        let nodes = nodes.clone();
        handles.push(std::thread::spawn(move || {
            for iter in 0..25 {
                let v = (w * 1000 + iter + 1) as f32;
                let layer: Vec<f32> = vec![v; nodes.len() * h];
                store.push(&nodes, &[layer.clone(), layer]).unwrap();
            }
        }));
    }
    for _ in 0..3 {
        let store = Arc::clone(&store);
        let nodes = nodes.clone();
        handles.push(std::thread::spawn(move || {
            let mut buf = Vec::new();
            for _ in 0..50 {
                store.pull_into(&nodes, false, &mut buf).unwrap();
                for layer in &buf {
                    for row in layer.chunks_exact(h) {
                        assert!(
                            row.iter().all(|&x| x == row[0]),
                            "torn row under rebalance: {row:?}"
                        );
                        assert!(row[0] != 0.0, "row lost under rebalance");
                    }
                }
            }
        }));
    }
    {
        let store = Arc::clone(&store);
        handles.push(std::thread::spawn(move || {
            let uniform = ShardMap::uniform(4, 1).unwrap();
            let rotated = uniform.excluding(3).unwrap();
            for i in 0..8 {
                let map = if i % 2 == 0 { rotated.clone() } else { uniform.clone() };
                store.rebalance(map).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    for t in handles {
        t.join().unwrap();
    }

    let st = store.stats().unwrap();
    assert_eq!((st.nodes, st.rows), (128, 256));
    assert_eq!(st.epoch, 8);
    assert_eq!(st.failovers, 0, "fault-free hammer must not fail over");
    // final state: every row readable, uniform, and on the uniform map
    // again after the even number of flips
    assert_eq!(store.map().replicas(), 1);
    let (rows, _) = store.pull(&nodes, false).unwrap();
    for layer in &rows {
        for row in layer.chunks_exact(h) {
            assert!(row.iter().all(|&x| x == row[0]) && row[0] != 0.0);
        }
    }
}
