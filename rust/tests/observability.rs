//! Observability-plane acceptance tests (DESIGN.md §16).
//!
//! Three pillars, three kinds of evidence:
//!
//! 1. **Metrics** — property tests over the log-bucketed [`Histogram`]
//!    (bucket bounds tile `u64` with ≤1/16 relative error, merge is a
//!    bucket-wise sum so it matches recording everything into one
//!    histogram, quantiles are monotone), plus a render→parse round trip
//!    of the Prometheus-style exposition and a live wire op=6 (STATSX)
//!    scrape against a real daemon.
//! 2. **Tracing** — property tests over the ring-buffered [`Tracer`]
//!    (bounded memory under floods, every export is balanced B/E JSON
//!    with per-thread nesting depth that never goes negative).
//! 3. **Pure observer** — the load-bearing guarantee: a child `optimes
//!    run` with `--trace` produces the bit-identical accuracy curve and
//!    bit-identical `session.ckpt` bytes as the same run without it.
//!    Tracing is latched per process (`OPTIMES_TRACE` is read once), so
//!    the on/off arms must be separate child processes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::Command;

use optimes::obs::metrics::{bucket_hi, bucket_lo, bucket_of, bucket_width, HIST_BUCKETS};
use optimes::obs::{parse_exposition, Histogram, Registry, SpanRecord, Tracer};
use optimes::util::json::Json;
use optimes::util::proptest::check;
use optimes::{prop_assert, prop_assert_eq};

// ---------------------------------------------------------------- histogram

#[test]
fn hist_buckets_tile_u64_and_bound_error() {
    // Every value lands in a bucket whose [lo, hi] range contains it, and
    // past the linear region the bucket is at most v/16 wide (the 1/16
    // relative-error contract the quantile API inherits).
    check(
        "hist_bucket_bounds",
        400,
        |g| {
            // spread cases across the full u64 dynamic range
            let shift = g.int(0, 63) as u32;
            let base = 1u64.checked_shl(shift).unwrap_or(u64::MAX);
            base.saturating_add(g.int_scaled(0, 1_000_000) as u64)
        },
        |&v| {
            let b = bucket_of(v);
            prop_assert!(b < HIST_BUCKETS, "bucket index {b} out of range for {v}");
            let (lo, hi) = (bucket_lo(b), bucket_hi(b));
            prop_assert!(lo <= v && v <= hi, "{v} outside bucket {b} = [{lo}, {hi}]");
            prop_assert_eq!(bucket_width(b), hi - lo + 1);
            prop_assert!(
                hi - lo + 1 <= (v / 16).max(1),
                "bucket {b} = [{lo}, {hi}] wider than {v}/16"
            );
            // adjacent buckets tile: no gaps, no overlaps
            if b + 1 < HIST_BUCKETS {
                prop_assert_eq!(bucket_lo(b + 1), hi + 1);
            }
            Ok(())
        },
    );
}

#[test]
fn hist_merge_matches_single_histogram_and_commutes() {
    check(
        "hist_merge",
        60,
        |g| {
            let sample = |g: &mut optimes::util::proptest::Gen| -> Vec<u64> {
                (0..g.int_scaled(0, 200))
                    .map(|_| (g.f64() * 1e12) as u64)
                    .collect()
            };
            (sample(g), sample(g))
        },
        |(a, b)| {
            let (ha, hb, combined) = (Histogram::new(), Histogram::new(), Histogram::new());
            for &v in a {
                ha.record(v);
                combined.record(v);
            }
            for &v in b {
                hb.record(v);
                combined.record(v);
            }
            // a ∪ b == record-everything-into-one
            let merged = Histogram::new();
            merged.merge_from(&ha);
            merged.merge_from(&hb);
            prop_assert_eq!(merged.bucket_counts(), combined.bucket_counts());
            prop_assert_eq!(merged.count(), combined.count());
            prop_assert_eq!(merged.sum(), combined.sum());
            for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
                prop_assert_eq!(merged.quantile(q), combined.quantile(q));
            }
            // b ∪ a == a ∪ b
            let flipped = Histogram::new();
            flipped.merge_from(&hb);
            flipped.merge_from(&ha);
            prop_assert_eq!(flipped.bucket_counts(), merged.bucket_counts());
            Ok(())
        },
    );
}

#[test]
fn hist_quantiles_are_monotone_and_bracket_the_samples() {
    check(
        "hist_quantile_monotone",
        60,
        |g| {
            let n = 1 + g.int_scaled(0, 300);
            let samples: Vec<u64> = (0..n).map(|_| (g.f64() * 1e9) as u64).collect();
            let qs: Vec<f64> = (0..8).map(|_| g.f64()).collect();
            (samples, qs)
        },
        |(samples, qs)| {
            let h = Histogram::new();
            for &v in samples {
                h.record(v);
            }
            let mut sorted = qs.clone();
            sorted.sort_by(f64::total_cmp);
            let mut prev = 0u64;
            for &q in &sorted {
                let v = h.quantile(q);
                prop_assert!(
                    v >= prev,
                    "quantile not monotone: q={q} gave {v} after {prev}"
                );
                prev = v;
            }
            // the reported quantile is a bucket upper bound, so it can only
            // sit at or above the true order statistic
            let (min, max) = (
                *samples.iter().min().unwrap(),
                *samples.iter().max().unwrap(),
            );
            prop_assert!(h.quantile(0.0) >= min, "q0 below the minimum sample");
            prop_assert!(h.quantile(1.0) >= max, "q1 below the maximum sample");
            prop_assert!(
                h.quantile(1.0) <= bucket_hi(bucket_of(max)),
                "q1 above the max sample's bucket"
            );
            Ok(())
        },
    );
}

#[test]
fn empty_histogram_is_all_zeros() {
    let h = Histogram::new();
    assert_eq!(h.count(), 0);
    assert_eq!(h.sum(), 0);
    assert_eq!(h.mean(), 0.0);
    assert_eq!(h.quantile(0.5), 0);
}

// --------------------------------------------------------------- exposition

#[test]
fn exposition_renders_and_parses_round_trip() {
    let r = Registry::new();
    r.counter("optimes_test_ops").add(42);
    r.gauge("optimes_test_depth").set(-7);
    let h = r.histogram("optimes_test_latency_ns");
    for v in [10_000u64, 20_000, 30_000, 4_000_000] {
        h.record(v);
    }
    let text = r.render();
    assert!(text.contains("# TYPE optimes_test_ops counter"), "{text}");
    assert!(text.contains("# TYPE optimes_test_depth gauge"), "{text}");
    assert!(text.contains("# TYPE optimes_test_latency_ns summary"), "{text}");

    let m: BTreeMap<String, f64> = parse_exposition(&text);
    assert_eq!(m.get("optimes_test_ops"), Some(&42.0));
    assert_eq!(m.get("optimes_test_depth"), Some(&-7.0));
    assert_eq!(m.get("optimes_test_latency_ns_count"), Some(&4.0));
    let sum = m["optimes_test_latency_ns_sum"];
    assert_eq!(sum as u64, h.sum());
    let p50 = m["optimes_test_latency_ns{quantile=\"0.5\"}"];
    let p999 = m["optimes_test_latency_ns{quantile=\"0.999\"}"];
    assert!(p50 >= 20_000.0 && p50 <= p999, "p50 {p50} p999 {p999}");
    assert!(p999 >= 4_000_000.0, "p999 {p999} misses the tail sample");
}

#[test]
fn statsx_scrape_reports_stored_rows_and_rpc_latency() {
    use optimes::coordinator::{EmbServerDaemon, EmbeddingServer, NetConfig, RemoteEmbClient};
    use std::sync::Arc;
    const LAYERS: usize = 2;
    const HIDDEN: usize = 16;

    let slab = Arc::new(EmbeddingServer::new(LAYERS, HIDDEN, NetConfig::default()));
    let daemon = EmbServerDaemon::start(slab, "127.0.0.1:0").expect("daemon start");
    let addr = daemon.addr.to_string();

    let mut c = RemoteEmbClient::connect(addr.as_str(), LAYERS, HIDDEN).expect("connect");
    let nodes: Vec<u32> = (0..8).collect();
    let layer: Vec<f32> = (0..nodes.len() * HIDDEN).map(|i| i as f32 * 0.5).collect();
    c.push(&nodes, &vec![layer; LAYERS]).expect("push");
    c.pull(&nodes).expect("pull");

    let text = c.statsx().expect("statsx");
    let m = parse_exposition(&text);
    assert_eq!(m.get("optimes_store_nodes"), Some(&8.0), "{text}");
    assert_eq!(
        m.get("optimes_store_rows"),
        Some(&((8 * LAYERS) as f64)),
        "{text}"
    );
    for hist in ["optimes_daemon_rpc_push_ns", "optimes_daemon_rpc_pull_ns"] {
        assert_eq!(m.get(&format!("{hist}_count")), Some(&1.0), "{text}");
        let p99 = m[&format!("{hist}{{quantile=\"0.99\"}}")];
        assert!(p99 > 0.0, "{hist} p99 is zero:\n{text}");
    }
    // the scrape itself is a control op and must not count as an RPC
    let again = parse_exposition(&c.statsx().expect("second statsx"));
    assert_eq!(again.get("optimes_daemon_rpc_pull_ns_count"), Some(&1.0));
    daemon.shutdown();
}

// ------------------------------------------------------------------- tracer

#[test]
fn tracer_ring_is_bounded_under_floods() {
    check(
        "tracer_bounded",
        40,
        |g| (1 + g.int_scaled(0, 64), g.int_scaled(0, 500)),
        |&(cap, n)| {
            let t = Tracer::new(cap);
            t.set_enabled(true);
            for i in 0..n {
                t.record(SpanRecord {
                    name: "flood",
                    cat: "test",
                    start_ns: i as u64,
                    end_ns: i as u64 + 1,
                    tid: 1,
                    args: Vec::new(),
                    instant: false,
                });
            }
            prop_assert!(t.len() <= cap, "ring grew past capacity: {} > {cap}", t.len());
            prop_assert_eq!(t.len(), n.min(cap));
            prop_assert_eq!(t.dropped(), n.saturating_sub(cap) as u64);
            Ok(())
        },
    );
}

#[test]
fn tracer_export_is_balanced_and_never_nests_negative() {
    check(
        "tracer_nesting",
        40,
        |g| {
            // random span soup: overlapping intervals, several threads,
            // a few instants sprinkled in
            let n = 1 + g.int_scaled(0, 80);
            (0..n)
                .map(|_| {
                    let start = g.int_scaled(0, 10_000) as u64;
                    let dur = g.int(0, 5_000) as u64;
                    (start, start + dur, 1 + g.int(0, 3) as u64, g.bool())
                })
                .collect::<Vec<_>>()
        },
        |spans| {
            let t = Tracer::new(4096);
            t.set_enabled(true);
            for &(start_ns, end_ns, tid, instant) in spans {
                t.record(SpanRecord {
                    name: "s",
                    cat: "test",
                    start_ns,
                    end_ns,
                    tid,
                    args: vec![("k", "v".to_string())],
                    instant,
                });
            }
            let json = t.export_json();
            let doc = Json::parse(&json).map_err(|e| format!("export not JSON: {e:?}"))?;
            // Chrome's "JSON Array Format": a bare top-level event array
            let events = doc.as_arr().ok_or("export is not an array")?;
            let n_spans = spans.iter().filter(|s| !s.3).count();
            let n_instants = spans.len() - n_spans;
            prop_assert_eq!(events.len(), n_spans * 2 + n_instants);
            let mut depth: BTreeMap<u64, i64> = BTreeMap::new();
            let (mut b, mut e, mut i) = (0usize, 0usize, 0usize);
            let mut last_ts = f64::MIN;
            for ev in events {
                let ph = ev.at("ph").as_str().ok_or("event without ph")?;
                let ts = ev.at("ts").as_f64().ok_or("event without ts")?;
                let tid = ev.at("tid").as_f64().ok_or("event without tid")? as u64;
                prop_assert!(ts >= last_ts, "timestamps regress: {ts} after {last_ts}");
                last_ts = ts;
                let d = depth.entry(tid).or_insert(0);
                match ph {
                    "B" => {
                        b += 1;
                        *d += 1;
                    }
                    "E" => {
                        e += 1;
                        *d -= 1;
                        prop_assert!(*d >= 0, "tid {tid} closed more spans than it opened");
                    }
                    "i" => i += 1,
                    other => prop_assert!(false, "unexpected ph {other:?}"),
                }
            }
            prop_assert_eq!(b, e);
            prop_assert_eq!(b, n_spans);
            prop_assert_eq!(i, n_instants);
            for (tid, d) in &depth {
                prop_assert!(*d == 0, "tid {tid} ends at depth {d}");
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ pure observer

fn scratch_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("optimes-obs-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("scratch dir");
    d
}

/// Run `optimes run` in a child process on a miniature sharded, pipelined
/// session and return its stdout. The trace/no-trace arms must be separate
/// processes: `OPTIMES_TRACE` is latched once per process by design.
fn run_child(ckpt: &Path, trace: Option<&Path>) -> String {
    let exe = env!("CARGO_BIN_EXE_optimes");
    let mut cmd = Command::new(exe);
    cmd.args([
        "run",
        "--dataset",
        "arxiv-s",
        "--scale",
        "40",
        "--clients",
        "2",
        "--rounds",
        "2",
        "--epochs",
        "1",
        "--epoch-batches",
        "2",
        "--eval-batches",
        "2",
        "--fanout",
        "3",
        "--seed",
        "7",
        "--sequential",
        "--shards",
        "2",
        "--pipeline",
        "on",
        "--checkpoint",
    ])
    .arg(ckpt)
    .env_remove("OPTIMES_TRACE")
    .env_remove("OPTIMES_TRACE_CAP")
    .env_remove("OPTIMES_LOG");
    if let Some(path) = trace {
        cmd.arg("--trace").arg(path);
    }
    let out = cmd.output().expect("spawn optimes run");
    assert!(
        out.status.success(),
        "child run failed (trace={}):\nstdout: {}\nstderr: {}",
        trace.is_some(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Canonical checkpoint bytes with the wall-clock timing fields zeroed.
/// `session.ckpt` serializes per-round wall times (`round_time`, phase
/// means, critical path), which legitimately differ between *any* two
/// runs — traced or not. Everything else (weights, RNG cursors, store
/// snapshot, membership ledger, accuracy/val-loss curve, byte meters)
/// must be bit-identical, so we scrub only the clocks and compare the
/// re-encoded bundle byte for byte.
fn scrubbed_ckpt_bytes(dir: &Path, resave_into: &Path) -> Vec<u8> {
    use optimes::coordinator::metrics::PhaseTimes;
    let mut bundle = optimes::coordinator::CheckpointBundle::load(dir).expect("load checkpoint");
    for r in &mut bundle.metrics.rounds {
        r.round_time = 0.0;
        r.mean_phases = PhaseTimes::default();
        r.critical = PhaseTimes::default();
    }
    std::fs::create_dir_all(resave_into).expect("scrub dir");
    let path = bundle.save(resave_into).expect("re-save checkpoint");
    std::fs::read(path).expect("scrubbed checkpoint bytes")
}

/// Everything accuracy-shaped in the run's stdout: the per-round curve
/// plus the smoothed-accuracy summary line. Timing numbers are excluded
/// (wall clock legitimately differs run to run); the *curve* may not.
fn accuracy_fingerprint(stdout: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in stdout.lines() {
        if line.starts_with("round ") {
            let acc = line
                .split("acc ")
                .nth(1)
                .and_then(|r| r.split('%').next())
                .unwrap_or_else(|| panic!("unparseable round line: {line}"));
            out.push(format!("acc {}", acc.trim()));
        }
        if let Some(rest) = line.trim_start().strip_prefix("smoothed accuracy:") {
            out.push(format!("smoothed{rest}"));
        }
    }
    assert!(out.len() >= 3, "no curve found in stdout:\n{stdout}");
    out
}

#[test]
fn tracing_is_a_pure_observer_bit_identical_curve_and_checkpoint() {
    let root = scratch_dir("parity");
    let trace_path = root.join("run.trace.json");
    let ckpt_on = root.join("ckpt-on");
    let ckpt_off = root.join("ckpt-off");

    let stdout_on = run_child(&ckpt_on, Some(&trace_path));
    let stdout_off = run_child(&ckpt_off, None);

    // identical accuracy curves...
    assert_eq!(
        accuracy_fingerprint(&stdout_on),
        accuracy_fingerprint(&stdout_off),
        "tracing changed the accuracy curve"
    );
    // ...and bit-identical checkpoint bytes (model weights, RNG cursors,
    // store snapshot, curve) once the wall-clock-only fields are scrubbed
    let ckpt_a = scrubbed_ckpt_bytes(&ckpt_on, &root.join("scrub-on"));
    let ckpt_b = scrubbed_ckpt_bytes(&ckpt_off, &root.join("scrub-off"));
    assert_eq!(ckpt_a, ckpt_b, "tracing changed the session.ckpt bytes");

    // the traced arm must actually have produced a usable timeline
    let text = std::fs::read_to_string(&trace_path).expect("trace file");
    let doc = Json::parse(&text).expect("trace parses as JSON");
    let events = doc.as_arr().expect("trace is a bare event array");
    assert!(!events.is_empty(), "trace is empty");
    let mut names = std::collections::BTreeSet::new();
    let (mut b, mut e) = (0usize, 0usize);
    for ev in events {
        match ev.at("ph").as_str() {
            Some("B") => b += 1,
            Some("E") => e += 1,
            _ => {}
        }
        if let Some(n) = ev.at("name").as_str() {
            names.insert(n.to_string());
        }
    }
    assert_eq!(b, e, "unbalanced B/E in trace");
    for expected in [
        "round",
        "broadcast",
        "clients",
        "aggregate",
        "validate",
        "epoch",
        "batch",
        "push_embed",
        "push_fanout",
        "pull_fanout",
        "checkpoint",
    ] {
        assert!(
            names.contains(expected),
            "trace lacks a {expected:?} span; saw {names:?}"
        );
    }

    let _ = std::fs::remove_dir_all(&root);
}
