//! Churn-chaos suite for elastic membership and whole-session
//! checkpoint/resume (DESIGN.md §14).
//!
//! The contracts under test:
//!
//! * a **zero-churn** spec plus the checkpoint plane is structurally
//!   inert — accuracy curves are bit-identical to a session built
//!   without either, on the slab / TCP / sharded backends, pipeline on
//!   and off;
//! * a session under a scripted join/leave schedule **completes** and
//!   lands within tolerance of the static run;
//! * **kill-and-resume** at a round boundary reproduces the
//!   uninterrupted accuracy curve bit-for-bit, across
//!   {slab, sharded+replicated} × {pipeline on, off} × {raw, int8},
//!   and also across a churn event;
//! * resuming against the wrong graph, a mismatched codec, or a
//!   mismatched config is a **loud** error, and a corrupted bundle
//!   never loads.
//!
//! Like `fault_tolerance.rs`, every session runs sequential clients
//! (deterministic push/pull order is what makes curves comparable
//! bit-for-bit) and forces the pipeline explicitly, independent of the
//! `OPTIMES_PIPELINE` matrix the CI lifecycle job applies to the rest
//! of the tree.

use std::path::PathBuf;
use std::sync::Arc;

use optimes::coordinator::{
    ChurnSpec, EmbServerDaemon, EmbeddingServer, EmbeddingStore, NetConfig, SessionBuilder,
    SessionConfig, SessionMetrics, ShardedStore, Strategy, TcpEmbeddingStore, CHECKPOINT_FILE,
};
use optimes::graph::datasets::tiny;
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};
use optimes::wire::CodecSpec;

const HIDDEN: usize = 16;
const N_LAYERS: usize = 2; // layers - 1
const SHARDS: usize = 4;
const ROUNDS: usize = 6;
const SEED: u64 = 411;

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: HIDDEN,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn cfg(pipeline: bool, churn: &str) -> SessionConfig {
    SessionConfig {
        strategy: Strategy::e(),
        rounds: ROUNDS,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        // sequential clients: deterministic push/pull order makes the
        // accuracy curves comparable bit-for-bit across runs
        parallel_clients: false,
        pipeline,
        churn: ChurnSpec::parse(churn).unwrap(),
        ..Default::default()
    }
}

/// Fresh empty backend of the named kind (each session needs its own).
fn backend(kind: &str) -> Arc<dyn EmbeddingStore> {
    match kind {
        "slab" => Arc::new(EmbeddingServer::new(N_LAYERS, HIDDEN, NetConfig::default())),
        "sharded" => Arc::new(
            ShardedStore::in_process_replicated(SHARDS, 1, N_LAYERS, HIDDEN, NetConfig::default())
                .unwrap(),
        ),
        other => unreachable!("backend {other}"),
    }
}

fn wrap_codec(store: Arc<dyn EmbeddingStore>, codec: &str) -> Arc<dyn EmbeddingStore> {
    CodecSpec::parse(codec).unwrap().wrap_store(store, NetConfig::default())
}

/// Unique per-test checkpoint directory, cleared on entry.
fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("optimes-lifecycle-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn run_plain(store: Arc<dyn EmbeddingStore>, cfg: &SessionConfig, seed: u64) -> SessionMetrics {
    let g = tiny(seed);
    SessionBuilder::new(cfg.clone())
        .store(store)
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap()
}

fn assert_same_curve(a: &SessionMetrics, b: &SessionMetrics, what: &str) {
    assert_eq!(a.accuracies(), b.accuracies(), "accuracy curves diverged: {what}");
    let va: Vec<f64> = a.rounds.iter().map(|r| r.val_loss).collect();
    let vb: Vec<f64> = b.rounds.iter().map(|r| r.val_loss).collect();
    assert_eq!(va, vb, "validation losses diverged: {what}");
    assert_eq!(a.server_embeddings, b.server_embeddings, "store contents diverged: {what}");
}

// ---------------------------------------------------------------------------
// zero-churn spec + checkpoint plane: structurally inert
// ---------------------------------------------------------------------------

#[test]
fn zero_churn_and_checkpointing_are_bit_identical() {
    for pipeline in [false, true] {
        for kind in ["slab", "sharded"] {
            let base = run_plain(backend(kind), &cfg(pipeline, ""), SEED);
            assert_eq!(base.rounds.len(), ROUNDS);
            // every round of the static run reports the full stable roster
            for r in &base.rounds {
                assert_eq!(r.active_clients, vec![0, 1, 2, 3]);
            }

            let dir = temp_dir(&format!("inert-{kind}-{pipeline}"));
            let g = tiny(SEED);
            let m = SessionBuilder::new(cfg(pipeline, ""))
                .store(backend(kind))
                .checkpoints(&dir, 2)
                .build(&g, ref_engine())
                .unwrap()
                .run()
                .unwrap();
            // the snapshot plane shows up in the backend description...
            assert!(
                m.store_backend.starts_with("snapshot("),
                "checkpointing session must run through the snapshot plane, got {}",
                m.store_backend
            );
            // ...but never in the values
            assert_same_curve(&base, &m, &format!("{kind} pipeline={pipeline}"));
            assert!(dir.join(CHECKPOINT_FILE).exists(), "no bundle written");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn zero_churn_is_bit_identical_over_tcp() {
    for pipeline in [false, true] {
        let mk_tcp = || {
            let daemon = EmbServerDaemon::start(backend("slab"), "127.0.0.1:0").unwrap();
            let store: Arc<dyn EmbeddingStore> = Arc::new(
                TcpEmbeddingStore::connect(daemon.addr.to_string(), N_LAYERS, HIDDEN).unwrap(),
            );
            (daemon, store)
        };
        let (_d1, s1) = mk_tcp();
        let base = run_plain(s1, &cfg(pipeline, ""), SEED);

        let (_d2, s2) = mk_tcp();
        let dir = temp_dir(&format!("inert-tcp-{pipeline}"));
        let g = tiny(SEED);
        let m = SessionBuilder::new(cfg(pipeline, ""))
            .store(s2)
            .checkpoints(&dir, 3)
            .build(&g, ref_engine())
            .unwrap()
            .run()
            .unwrap();
        assert_same_curve(&base, &m, &format!("tcp pipeline={pipeline}"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// scripted churn: the session completes and stays close to the static run
// ---------------------------------------------------------------------------

#[test]
fn churn_schedule_completes_with_sane_curve() {
    for pipeline in [false, true] {
        let static_run = run_plain(backend("slab"), &cfg(pipeline, ""), SEED);
        let m = run_plain(backend("slab"), &cfg(pipeline, "leave@2:1,join@4"), SEED);
        assert_eq!(m.rounds.len(), ROUNDS);
        for r in &m.rounds {
            assert!(r.accuracy.is_finite() && (0.0..=1.0).contains(&r.accuracy));
            assert!(r.val_loss.is_finite());
        }
        // the roster tracks the schedule round by round
        assert_eq!(m.rounds[0].active_clients, vec![0, 1, 2, 3]);
        assert_eq!(m.rounds[2].active_clients, vec![0, 2, 3], "leave@2 not applied");
        assert_eq!(m.rounds[4].active_clients, vec![0, 2, 3, 4], "join@4 not applied");
        assert_eq!(m.rounds[5].active_clients, vec![0, 2, 3, 4]);
        // churn shifts the curve but must not destroy learning
        let d = (static_run.peak_accuracy() - m.peak_accuracy()).abs();
        assert!(
            d <= 0.25,
            "pipeline={pipeline}: churned peak {:.3} too far from static {:.3}",
            m.peak_accuracy(),
            static_run.peak_accuracy()
        );
    }
}

#[test]
fn departures_down_to_one_client_still_run() {
    let m = run_plain(backend("slab"), &cfg(false, "leave@1:0,leave@2:2,leave@3:3"), SEED);
    assert_eq!(m.rounds.len(), ROUNDS);
    assert_eq!(m.rounds[ROUNDS - 1].active_clients, vec![1]);
    assert!(m.rounds[ROUNDS - 1].accuracy.is_finite());
}

#[test]
fn removing_an_unknown_client_fails_loudly() {
    let g = tiny(SEED);
    let err = SessionBuilder::new(cfg(false, "leave@1:9"))
        .store(backend("slab"))
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .err()
        .expect("leave of unknown client must fail");
    let chain = format!("{err:#}");
    assert!(chain.contains("not active"), "unexpected error chain: {chain}");
    assert!(chain.contains("churn before round 1"), "missing context: {chain}");
}

// ---------------------------------------------------------------------------
// kill-and-resume: bit-identical to the uninterrupted run
// ---------------------------------------------------------------------------

const KILL_AT: usize = 3; // rounds completed before the "crash"

/// Run `cfg` to `KILL_AT` rounds with checkpointing, drop the session
/// (the crash), resume from the bundle on a fresh store, and run to
/// completion. Returns the resumed session's full metrics.
fn kill_and_resume(
    cfg: &SessionConfig,
    mk_store: &dyn Fn() -> Arc<dyn EmbeddingStore>,
    dir: &PathBuf,
    seed: u64,
) -> SessionMetrics {
    let g = tiny(seed);
    {
        let mut session = SessionBuilder::new(cfg.clone())
            .store(mk_store())
            .checkpoints(dir, KILL_AT)
            .build(&g, ref_engine())
            .unwrap();
        session.pretrain().unwrap();
        while session.completed_rounds() < KILL_AT {
            session.run_round().unwrap();
        }
        // crash: the session is dropped without finish(); only the
        // bundle on disk survives
    }
    assert!(dir.join(CHECKPOINT_FILE).exists(), "no bundle at the kill point");
    SessionBuilder::new(cfg.clone())
        .store(mk_store())
        .resume(dir)
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn kill_and_resume_reproduces_uninterrupted_curve() {
    for kind in ["slab", "sharded"] {
        for pipeline in [false, true] {
            for codec in ["raw", "int8"] {
                let what = format!("{kind} pipeline={pipeline} codec={codec}");
                let mk_store = || wrap_codec(backend(kind), codec);
                let oracle = run_plain(mk_store(), &cfg(pipeline, ""), SEED);
                assert_eq!(oracle.rounds.len(), ROUNDS);

                let dir = temp_dir(&format!("resume-{kind}-{pipeline}-{codec}"));
                let resumed = kill_and_resume(&cfg(pipeline, ""), &mk_store, &dir, SEED);
                assert_eq!(resumed.rounds.len(), ROUNDS, "{what}: resumed run incomplete");
                assert_same_curve(&oracle, &resumed, &what);
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

#[test]
fn kill_and_resume_across_churn_events() {
    // leave fires before the kill point, join after: resume must replay
    // the recorded departure from the ledger AND still fire the join
    // from the persisted schedule
    for pipeline in [false, true] {
        let c = cfg(pipeline, "leave@1:0,join@4");
        let oracle = run_plain(backend("slab"), &c, SEED);
        let dir = temp_dir(&format!("resume-churn-{pipeline}"));
        let mk = || backend("slab");
        let resumed = kill_and_resume(&c, &mk, &dir, SEED);
        assert_same_curve(&oracle, &resumed, &format!("churn pipeline={pipeline}"));
        assert_eq!(resumed.rounds[ROUNDS - 1].active_clients, vec![1, 2, 3, 4]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// resume misuse: every mismatch is loud
// ---------------------------------------------------------------------------

/// Checkpoint a short run and hand back its directory.
fn checkpointed_dir(tag: &str, codec: &str) -> PathBuf {
    let dir = temp_dir(tag);
    let g = tiny(SEED);
    let mut session = SessionBuilder::new(cfg(false, ""))
        .store(wrap_codec(backend("slab"), codec))
        .checkpoints(&dir, 2)
        .build(&g, ref_engine())
        .unwrap();
    session.pretrain().unwrap();
    while session.completed_rounds() < 2 {
        session.run_round().unwrap();
    }
    dir
}

fn resume_err(dir: &PathBuf, cfg: &SessionConfig, store: Arc<dyn EmbeddingStore>, seed: u64) -> String {
    let g = tiny(seed);
    let err = SessionBuilder::new(cfg.clone())
        .store(store)
        .resume(dir)
        .build(&g, ref_engine())
        .err()
        .expect("mismatched resume must fail at build");
    format!("{err:#}")
}

#[test]
fn resume_with_wrong_graph_fails_loudly() {
    let dir = checkpointed_dir("wrong-graph", "raw");
    let chain = resume_err(&dir, &cfg(false, ""), backend("slab"), SEED + 1);
    assert!(chain.contains("graph fingerprint"), "unexpected error chain: {chain}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_with_mismatched_codec_fails_loudly() {
    let dir = checkpointed_dir("wrong-codec", "raw");
    let chain = resume_err(&dir, &cfg(false, ""), wrap_codec(backend("slab"), "int8"), SEED);
    assert!(chain.contains("wire codec"), "unexpected error chain: {chain}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_with_mismatched_config_fails_loudly() {
    let dir = checkpointed_dir("wrong-config", "raw");
    let mut seeded = cfg(false, "");
    seeded.seed = 7;
    let chain = resume_err(&dir, &seeded, backend("slab"), SEED);
    assert!(chain.contains("seed"), "unexpected error chain: {chain}");

    let mut strat = cfg(false, "");
    strat.strategy = Strategy::opp();
    let chain = resume_err(&dir, &strat, backend("slab"), SEED);
    assert!(chain.contains("strategy"), "unexpected error chain: {chain}");

    let churned = cfg(false, "join@5");
    let chain = resume_err(&dir, &churned, backend("slab"), SEED);
    assert!(chain.contains("churn schedule"), "unexpected error chain: {chain}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_bundle_never_loads() {
    let dir = checkpointed_dir("corrupt", "raw");
    let path = dir.join(CHECKPOINT_FILE);
    let clean = std::fs::read(&path).unwrap();
    // a flip anywhere — header, table, or payload — must be caught by a
    // checksum (checkpoint.rs unit tests probe every section
    // individually; this is the end-to-end file-level check)
    for off in [9, 60, clean.len() / 2, clean.len() - 1] {
        let mut bad = clean.clone();
        bad[off] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let g = tiny(SEED);
        let err = SessionBuilder::new(cfg(false, ""))
            .store(backend("slab"))
            .resume(&dir)
            .build(&g, ref_engine())
            .err()
            .unwrap_or_else(|| panic!("flip at {off} loaded fine"));
        let chain = format!("{err:#}");
        assert!(chain.contains("checkpoint"), "flip at {off}: unexpected chain: {chain}");
    }
    // truncation too
    std::fs::write(&path, &clean[..clean.len() / 3]).unwrap();
    let g = tiny(SEED);
    assert!(SessionBuilder::new(cfg(false, ""))
        .store(backend("slab"))
        .resume(&dir)
        .build(&g, ref_engine())
        .is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// run-state machine
// ---------------------------------------------------------------------------

#[test]
fn run_state_walks_warmup_rounds_cooldown() {
    use optimes::coordinator::RunState;
    let g = tiny(SEED);
    let mut session = SessionBuilder::new(cfg(false, ""))
        .store(backend("slab"))
        .build(&g, ref_engine())
        .unwrap();
    assert_eq!(session.run_state(), RunState::Warmup);
    session.pretrain().unwrap();
    assert_eq!(session.run_state(), RunState::Rounds);
    session.run_round().unwrap();
    assert_eq!(session.run_state(), RunState::Rounds);
    assert_eq!(session.active_clients(), vec![0, 1, 2, 3]);
    let m = session.finish();
    assert_eq!(m.rounds.len(), 1);
}

#[test]
fn resumed_session_starts_in_rounds_state() {
    use optimes::coordinator::RunState;
    let dir = checkpointed_dir("state", "raw");
    let g = tiny(SEED);
    let session = SessionBuilder::new(cfg(false, ""))
        .store(backend("slab"))
        .resume(&dir)
        .build(&g, ref_engine())
        .unwrap();
    assert_eq!(session.run_state(), RunState::Rounds, "resume must skip warmup");
    assert_eq!(session.completed_rounds(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}
