//! Semi-synchronous round advancement under injected client latency
//! (DESIGN.md §12). Two families of guarantees:
//!
//! 1. **Structural parity.** With zero injected latency, every policy's
//!    release plan degenerates to the synchronous barrier (`release = 0`,
//!    everyone on time), so `quorum:K` and `deadline:S` must reproduce
//!    the `sync` accuracy curve *bit-for-bit* — on the plain slab store
//!    and on the sharded/replicated plane, pipeline off and on. This is
//!    what lets the CI `round-policy` matrix rerun the whole chaos and
//!    parity suites under `quorum:3` without golden-file churn.
//!
//! 2. **The straggler win.** Under a heavy-tailed lognormal latency
//!    model, `quorum:K` must reach the sync run's accuracy (±1 pt) in a
//!    fraction of the virtual time, while actually exercising the
//!    bounded-staleness fold (late updates folded with decayed weight,
//!    not silently discarded).
//!
//! Latency here is *injected model time*, deterministic per
//! `(client, round)` — never measured wall time — so every assertion
//! below is exact and seed-stable (the same invariant
//! `tests/store_parity.rs` leans on).

use std::sync::Arc;

use optimes::coordinator::{
    ClientLatency, NetConfig, RoundPolicySpec, SessionBuilder, SessionConfig, SessionMetrics,
    ShardedStore, Strategy,
};
use optimes::graph::datasets::tiny;
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};

const HIDDEN: usize = 16;
const N_LAYERS: usize = 2; // layers - 1
const ROUNDS: usize = 8;
const CLIENTS: usize = 4;

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: HIDDEN,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

fn cfg(
    policy: RoundPolicySpec,
    latency: Option<ClientLatency>,
    pipeline: bool,
) -> SessionConfig {
    SessionConfig {
        clients: CLIENTS,
        strategy: Strategy::e(),
        rounds: ROUNDS,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        // sequential clients: deterministic push/pull order makes the
        // accuracy curves comparable bit-for-bit across runs
        parallel_clients: false,
        pipeline,
        round_policy: policy,
        staleness: 2,
        net: NetConfig { client_latency: latency, ..NetConfig::default() },
        ..Default::default()
    }
}

fn run(config: SessionConfig, seed: u64) -> SessionMetrics {
    let g = tiny(seed);
    SessionBuilder::new(config)
        .build(&g, ref_engine())
        .unwrap()
        .run()
        .unwrap()
}

fn assert_same_curve(a: &SessionMetrics, b: &SessionMetrics) {
    assert_eq!(a.accuracies(), b.accuracies(), "accuracy curves diverged");
    let va: Vec<f64> = a.rounds.iter().map(|r| r.val_loss).collect();
    let vb: Vec<f64> = b.rounds.iter().map(|r| r.val_loss).collect();
    assert_eq!(va, vb, "validation losses diverged");
    assert_eq!(a.server_embeddings, b.server_embeddings);
}

fn assert_no_straggler_activity(m: &SessionMetrics) {
    assert_eq!(m.total_stragglers_late(), 0, "[{}] saw late clients", m.round_policy);
    assert_eq!(m.total_stale_folded(), 0, "[{}] folded stale updates", m.round_policy);
    assert_eq!(m.total_stragglers_dropped(), 0, "[{}] dropped updates", m.round_policy);
    assert_eq!(m.total_quorum_wait(), 0.0, "[{}] waited on a quorum", m.round_policy);
}

// ---------------------------------------------------------------------------
// structural parity: zero latency => every policy is the sync barrier
// ---------------------------------------------------------------------------

#[test]
fn zero_latency_policies_match_sync_bitwise() {
    const SEED: u64 = 401;
    for pipeline in [false, true] {
        let sync = run(cfg(RoundPolicySpec::Sync, None, pipeline), SEED);
        assert_eq!(sync.round_policy, "sync");
        for policy in [
            RoundPolicySpec::Quorum { k: CLIENTS, slack: 0.0 },
            RoundPolicySpec::Quorum { k: 2, slack: 0.05 },
            RoundPolicySpec::Deadline { budget: 1.0 },
        ] {
            let m = run(cfg(policy.clone(), None, pipeline), SEED);
            assert_eq!(m.round_policy, policy.name());
            assert_same_curve(&sync, &m);
            assert_no_straggler_activity(&m);
        }
    }
}

#[test]
fn zero_latency_quorum_matches_sync_on_sharded_replicated_store() {
    const SEED: u64 = 403;
    let store = || {
        Arc::new(
            ShardedStore::in_process_replicated(4, 1, N_LAYERS, HIDDEN, NetConfig::default())
                .unwrap(),
        )
    };
    let g = tiny(SEED);
    let run_on = |policy: RoundPolicySpec| -> SessionMetrics {
        SessionBuilder::new(cfg(policy, None, false))
            .store(store())
            .build(&g, ref_engine())
            .unwrap()
            .run()
            .unwrap()
    };
    let sync = run_on(RoundPolicySpec::Sync);
    let quorum = run_on(RoundPolicySpec::Quorum { k: 3, slack: 0.0 });
    assert_same_curve(&sync, &quorum);
    assert_no_straggler_activity(&quorum);
}

// ---------------------------------------------------------------------------
// the straggler win: heavy-tailed latency, quorum advances early
// ---------------------------------------------------------------------------

#[test]
fn quorum_beats_sync_tta_under_heavy_tail() {
    const SEED: u64 = 405;
    let latency = ClientLatency::parse("lognormal:-0.9:1.5:11").unwrap();
    let sync = run(cfg(RoundPolicySpec::Sync, Some(latency), false), SEED);
    let quorum = run(
        cfg(RoundPolicySpec::Quorum { k: 3, slack: 0.1 }, Some(latency), false),
        SEED,
    );

    // the quorum run genuinely exercised the semi-synchronous path:
    // somebody was late, and their update folded (or aged out) rather
    // than being silently discarded
    assert!(quorum.total_stragglers_late() > 0, "no client was ever late");
    assert!(
        quorum.total_stale_folded() + quorum.total_stragglers_dropped() > 0,
        "late updates neither folded nor dropped"
    );
    assert!(
        quorum.rounds.iter().any(|r| r.stale_weight_applied > 0.0),
        "stale folds applied no decayed weight"
    );
    // sync, by definition, has no stragglers even under latency
    assert_no_straggler_activity(&sync);

    // both runs learn: same data, same model, quorum within a point
    assert!(sync.peak_accuracy() > 0.4, "sync never learned: {}", sync.peak_accuracy());
    assert!(quorum.peak_accuracy() > 0.4, "quorum never learned: {}", quorum.peak_accuracy());
    assert!(
        (sync.peak_accuracy() - quorum.peak_accuracy()).abs() < 0.1,
        "peaks diverged: sync {} vs quorum {}",
        sync.peak_accuracy(),
        quorum.peak_accuracy()
    );

    // ...and the quorum run gets there much faster in virtual time,
    // because each round releases after the 3rd report instead of the
    // heavy-tailed maximum
    let target = optimes::coordinator::metrics::paper_target_accuracy(&[&sync, &quorum]);
    let tta_sync = sync.time_to_accuracy(target).expect("sync never hit target");
    let tta_quorum = quorum.time_to_accuracy(target).expect("quorum never hit target");
    assert!(
        tta_quorum <= 0.5 * tta_sync,
        "quorum TTA {tta_quorum:.3}s not <= half of sync TTA {tta_sync:.3}s"
    );
    assert!(quorum.total_time() < sync.total_time());
}

// ---------------------------------------------------------------------------
// determinism + serialization of the straggler accounting
// ---------------------------------------------------------------------------

#[test]
fn straggler_runs_are_deterministic_and_serializable() {
    const SEED: u64 = 407;
    let latency = ClientLatency::parse("lognormal:-0.9:1.5:11").unwrap();
    let mk = || run(cfg(RoundPolicySpec::Quorum { k: 3, slack: 0.1 }, Some(latency), false), SEED);
    let a = mk();
    let b = mk();
    assert_same_curve(&a, &b);
    assert_eq!(a.total_stragglers_late(), b.total_stragglers_late());
    assert_eq!(a.total_stale_folded(), b.total_stale_folded());
    assert_eq!(a.total_stragglers_dropped(), b.total_stragglers_dropped());
    assert_eq!(a.total_stale_weight(), b.total_stale_weight());
    assert_eq!(a.total_quorum_wait(), b.total_quorum_wait());

    let text = optimes::harness::report::session_to_json(&a).to_string_pretty();
    let back = optimes::harness::report::session_from_json(&text).expect("round-trip failed");
    assert_eq!(back.round_policy, a.round_policy);
    assert_eq!(back.total_stragglers_late(), a.total_stragglers_late());
    assert_eq!(back.total_stale_folded(), a.total_stale_folded());
    assert_eq!(back.total_stragglers_dropped(), a.total_stragglers_dropped());
    assert!((back.total_stale_weight() - a.total_stale_weight()).abs() < 1e-9);
    assert!((back.total_quorum_wait() - a.total_quorum_wait()).abs() < 1e-9);
}

#[test]
fn pipeline_does_not_change_straggler_accounting() {
    // lateness is decided on injected delays, never on measured wall
    // time, so the async pipeline must not perturb any of it
    const SEED: u64 = 409;
    let latency = ClientLatency::parse("lognormal:-0.9:1.5:11").unwrap();
    let off = run(cfg(RoundPolicySpec::Quorum { k: 3, slack: 0.1 }, Some(latency), false), SEED);
    let on = run(cfg(RoundPolicySpec::Quorum { k: 3, slack: 0.1 }, Some(latency), true), SEED);
    assert_same_curve(&off, &on);
    assert_eq!(off.total_stragglers_late(), on.total_stragglers_late());
    assert_eq!(off.total_stale_folded(), on.total_stale_folded());
    assert_eq!(off.total_stragglers_dropped(), on.total_stragglers_dropped());
    assert_eq!(off.total_stale_weight(), on.total_stale_weight());
    assert_eq!(off.total_quorum_wait(), on.total_quorum_wait());
}
