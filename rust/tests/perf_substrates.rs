//! Integration coverage for the perf substrates: the slab embedding
//! store's caller-buffer pulls, the zero-alloc `BatchScratch` assembly
//! path, the shared gather adjacency, and the cache-miss observability
//! wired through session metrics.

use std::sync::Arc;

use optimes::coordinator::trainer::{assemble_batch, BatchScratch};
use optimes::coordinator::{
    run_session, EmbCache, EmbeddingServer, NetConfig, SessionConfig, Strategy,
};
use optimes::graph::datasets::tiny;
use optimes::graph::partition::metis_lite;
use optimes::graph::sampler::Sampler;
use optimes::graph::subgraph::{build_all, Prune};
use optimes::runtime::{ModelGeom, ModelKind, RefEngine, StepEngine};

fn ref_engine() -> Arc<dyn StepEngine> {
    Arc::new(RefEngine::new(ModelGeom {
        model: ModelKind::Gc,
        layers: 3,
        feat: 32,
        hidden: 16,
        classes: 4,
        batch: 8,
        fanout: 3,
        push_batch: 8,
    }))
}

#[test]
fn pull_into_agrees_with_allocating_pull() {
    let s = EmbeddingServer::new(2, 16, NetConfig::default());
    let nodes: Vec<u32> = (0..500).collect();
    let l1: Vec<f32> = (0..nodes.len() * 16).map(|i| i as f32).collect();
    let l2: Vec<f32> = (0..nodes.len() * 16).map(|i| -(i as f32)).collect();
    s.push(&nodes, &[l1, l2]);
    let mixed: Vec<u32> = vec![499, 0, 777, 250, 13]; // 777 is missing
    let (alloc, _) = s.pull(&mixed, false);
    let mut buf = vec![vec![1.0f32; 3]]; // dirty + wrongly shaped
    s.pull_into(&mixed, false, &mut buf);
    assert_eq!(alloc, buf);
}

#[test]
fn scratch_reuse_across_train_and_embed_geometries() {
    // A single scratch must be safe to reuse across batches of different
    // depth/width (train depth L, embed depth L-1) with identical results
    // to fresh allocation each time.
    let g = tiny(57);
    let part = metis_lite(&g, 4, 2);
    let subs = build_all(&g, &part, &Prune::None, 5);
    let eng = ref_engine();
    let geom = *eng.geom();
    let dims = geom.dims();
    let sub = subs.iter().max_by_key(|s| s.n_remote()).unwrap();
    let cache = EmbCache::new(geom.layers - 1, geom.hidden, sub.n_remote());
    let adj_train = optimes::graph::sampler::static_adj(&dims, dims.batch, dims.layers);
    let adj_embed =
        optimes::graph::sampler::static_adj(&dims, dims.push_batch, dims.layers - 1);
    let mut sampler = Sampler::new(dims, 3, 0);
    let targets: Vec<u32> = sub.train_local.iter().copied().take(dims.batch).collect();
    let push: Vec<u32> = sub
        .push_nodes
        .iter()
        .filter_map(|gid| sub.local_index(*gid))
        .take(dims.push_batch)
        .collect();
    if targets.is_empty() || push.is_empty() {
        panic!("test graph produced no targets/push nodes");
    }
    let mut scratch = BatchScratch::default();
    for round in 0..3 {
        let tb = sampler.sample_batch(sub, &targets);
        let fresh = assemble_batch(&tb, sub, &cache, &g, &adj_train, true);
        let reused = scratch.assemble(&tb, sub, &cache, &g, &adj_train, true);
        assert_eq!(fresh.x, reused.x, "round {round} train x");
        assert_eq!(fresh.rmask, reused.rmask);
        assert_eq!(fresh.cache, reused.cache);
        assert_eq!(fresh.labels, reused.labels);

        let eb = sampler.sample_embed(sub, &push);
        let fresh = assemble_batch(&eb, sub, &cache, &g, &adj_embed, false);
        let reused = scratch.assemble(&eb, sub, &cache, &g, &adj_embed, false);
        assert_eq!(fresh.depth, reused.depth);
        assert_eq!(fresh.x, reused.x, "round {round} embed x");
        assert_eq!(fresh.rmask, reused.rmask);
        assert_eq!(fresh.cache, reused.cache);
        assert!(reused.labels.is_empty() && reused.lmask.is_empty());
    }
}

#[test]
fn scratch_batches_train_identically_to_fresh_batches() {
    // Driving the engine through scratch-assembled batches must produce
    // the exact same parameter trajectory as fresh allocation.
    let g = tiny(59);
    let part = metis_lite(&g, 4, 2);
    let subs = build_all(&g, &part, &Prune::None, 5);
    let eng = ref_engine();
    let geom = *eng.geom();
    let dims = geom.dims();
    let sub = &subs[0];
    let cache = EmbCache::new(geom.layers - 1, geom.hidden, sub.n_remote());
    let adj = optimes::graph::sampler::static_adj(&dims, dims.batch, dims.layers);
    let targets: Vec<u32> = sub.train_local.iter().copied().take(dims.batch).collect();

    let mut s1 = optimes::runtime::ModelState::init(&geom, 11);
    let mut s2 = s1.clone();
    let mut scratch = BatchScratch::default();
    let mut sampler_a = Sampler::new(dims, 21, 7);
    let mut sampler_b = Sampler::new(dims, 21, 7);
    for _ in 0..4 {
        let ba = sampler_a.sample_batch(sub, &targets);
        let bb = sampler_b.sample_batch(sub, &targets);
        let fresh = assemble_batch(&ba, sub, &cache, &g, &adj, true);
        let st1 = eng.train_step(&mut s1, &fresh, 0.01).unwrap();
        let reused = scratch.assemble(&bb, sub, &cache, &g, &adj, true);
        let st2 = eng.train_step(&mut s2, reused, 0.01).unwrap();
        assert_eq!(st1.loss, st2.loss);
    }
    assert_eq!(s1.params, s2.params);
}

#[test]
fn session_surfaces_cache_stats() {
    let g = tiny(71);
    let mk = |strategy| SessionConfig {
        strategy,
        rounds: 2,
        epochs: 2,
        epoch_batches: 4,
        eval_batches: 4,
        parallel_clients: false,
        ..Default::default()
    };
    // E pulls everything before training: lookups observed, zero misses
    let e = run_session(&g, &mk(Strategy::e()), ref_engine()).unwrap();
    let cs = e.cache_stats();
    assert!(cs.lookups > 0, "E session sampled no remote rows");
    assert_eq!(cs.misses, 0, "E must never assemble a missing remote row");
    assert_eq!(cs.miss_rate(), 0.0);
    // D exchanges nothing and retains no remotes: no lookups at all
    let d = run_session(&g, &mk(Strategy::d()), ref_engine()).unwrap();
    assert_eq!(d.cache_stats().lookups, 0);
    // the JSON report carries the counters
    let j = e.to_json();
    assert_eq!(j.at("cache_misses").as_usize(), Some(0));
    assert!(j.at("cache_lookups").as_usize().unwrap() > 0);
}
