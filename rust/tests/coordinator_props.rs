//! Property-based tests of coordinator invariants (using the in-tree
//! mini property-testing substrate, `util::proptest`):
//!
//! * routing   — every pulled row comes from the correct layer DB and the
//!   most recent push wins;
//! * batching  — OPP issues at most one on-demand RPC per minibatch and
//!   never re-pulls a cached node;
//! * state     — client cache is coherent with the server after
//!   push+pull; pruning never exceeds the retention limit;
//! * blocks    — every sampled block satisfies the AOT shape contract;
//! * shard map — routing is total (every id gets a full, distinct owner
//!   set), replicas never alias the primary, and a rebalance between two
//!   random maps moves exactly the rows whose owner set changed — no row
//!   lost, no row double-counted;
//! * wire codecs — lossless codecs round-trip bit-exactly, `f16`/`bf16`
//!   are idempotent within their stated precision, `Int8` stays inside
//!   its `(max − min)/510` per-row bound, `TopK` preserves exactly the
//!   K largest magnitudes, and a delta plane replayed through faults
//!   and rebalances converges to the same rows as full raw pushes
//!   (DESIGN.md §11);
//! * membership — incremental re-partition on churn preserves the
//!   disjoint-total-cover invariant and moves only the departed (or
//!   split) partition's vertices, and the ledger's apply/revert replay
//!   round-trips the partition bit-for-bit (DESIGN.md §14).

use std::sync::Arc;

use optimes::coordinator::{
    staleness_weight, Deadline, EmbCache, EmbeddingServer, EmbeddingStore, FaultStore, Membership,
    NetConfig, Quorum, RoundPolicy, ShardMap, ShardedStore, Synchronous,
};
use optimes::wire::{CodecKind, DeltaStore};
use optimes::graph::generate::{generate, GenParams};
use optimes::graph::partition::metis_lite;
use optimes::graph::sampler::{BlockDims, SampledNode, Sampler};
use optimes::graph::subgraph::{build_all, Prune};
use optimes::util::proptest::{check, Gen};
use optimes::{prop_assert, prop_assert_eq};

fn random_graph(g: &mut Gen) -> optimes::graph::Graph {
    let n = 100 + g.int_scaled(0, 800);
    generate(&GenParams {
        n,
        avg_degree: 3.0 + g.int(0, 12) as f64,
        communities: 2 + g.int(0, 6),
        classes: 4,
        feat_dim: 8,
        homophily: 0.5 + g.f64() * 0.45,
        hub_alpha: 1.2 + g.f64(),
        signal: 0.5,
        community_bias: g.f64() * 0.5,
        train_frac: 0.4,
        test_frac: 0.2,
        seed: g.int(0, 1_000_000) as u64,
    })
}

#[test]
fn prop_blocks_satisfy_aot_contract() {
    check(
        "blocks-shape-contract",
        25,
        |g| {
            let graph = random_graph(g);
            let k = 2 + g.int(0, 2);
            let batch = 2 + g.int(0, 6);
            let clients = 2 + g.int(0, 2);
            let seed = g.int(0, 9999) as u64;
            (graph, k, batch, clients, seed)
        },
        |(graph, k, batch, clients, seed)| {
            let part = metis_lite(graph, *clients, *seed);
            let subs = build_all(graph, &part, &Prune::None, *seed);
            let dims = BlockDims {
                layers: 3,
                fanout: *k,
                batch: *batch,
                feat: 8,
                hidden: 8,
                classes: 4,
                push_batch: *batch,
            };
            for sub in &subs {
                let mut sampler = Sampler::new(dims, *seed, sub.client_id as u64);
                let targets: Vec<u32> =
                    sub.train_local.iter().copied().take(*batch).collect();
                if targets.is_empty() {
                    continue;
                }
                let b = sampler.sample_batch(sub, &targets);
                // level sizes follow s_d = batch * (K+1)^d
                for d in 0..=3usize {
                    prop_assert_eq!(b.levels[d].len(), batch * (k + 1).pow(d as u32));
                }
                // prefix property
                for d in 0..3 {
                    prop_assert!(
                        b.levels[d + 1][..b.levels[d].len()] == b.levels[d][..],
                        "prefix property violated at level {d}"
                    );
                }
                // no remote at deepest; remote/pad children masked
                let prefix = b.levels[2].len();
                for n in &b.levels[3][prefix..] {
                    prop_assert!(
                        !matches!(n, SampledNode::Remote(_)),
                        "remote at hop L"
                    );
                }
                for d in 0..3usize {
                    for (i, parent) in b.levels[d].iter().enumerate() {
                        if !matches!(parent, SampledNode::Local(_)) {
                            for j in 0..*k {
                                prop_assert!(
                                    b.msk[d][i * k + j] == 0.0,
                                    "unmasked child of non-local parent"
                                );
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_retention_limit_enforced() {
    check(
        "retention-limit",
        25,
        |g| {
            let graph = random_graph(g);
            let limit = g.int(0, 5);
            let clients = 2 + g.int(0, 2);
            let seed = g.int(0, 9999) as u64;
            (graph, limit, clients, seed)
        },
        |(graph, limit, clients, seed)| {
            let part = metis_lite(graph, *clients, *seed);
            let subs = build_all(graph, &part, &Prune::Retention(*limit), *seed);
            for sub in &subs {
                for rems in &sub.in_remote {
                    prop_assert!(
                        rems.len() <= *limit,
                        "client {} kept {} remotes (limit {})",
                        sub.client_id,
                        rems.len(),
                        limit
                    );
                }
                // every push node must actually be pulled by someone
                for p in &sub.push_nodes {
                    let pulled = subs
                        .iter()
                        .any(|o| o.client_id != sub.client_id && o.remote.contains(p));
                    prop_assert!(pulled, "push node {p} pulled by nobody");
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_routing_last_push_wins() {
    check(
        "kv-routing",
        40,
        |g| {
            let layers = 1 + g.int(0, 2);
            let hidden = 1 + g.int(0, 7);
            let n = 1 + g.int_scaled(0, 200);
            let writes = 1 + g.int(0, 4);
            let seed = g.int(0, 9999) as u64;
            (layers, hidden, n, writes, seed)
        },
        |(layers, hidden, n, writes, seed)| {
            let server = EmbeddingServer::new(*layers, *hidden, NetConfig::default());
            let nodes: Vec<u32> = (0..*n as u32).map(|i| i * 7 + (*seed as u32 % 5)).collect();
            let mut last = Vec::new();
            for w in 0..*writes {
                let per_layer: Vec<Vec<f32>> = (0..*layers)
                    .map(|l| {
                        nodes
                            .iter()
                            .flat_map(|&nd| {
                                (0..*hidden)
                                    .map(move |j| (nd as f32) + (l as f32) * 0.1 + (w as f32) * 100.0 + j as f32)
                            })
                            .collect()
                    })
                    .collect();
                server.push(&nodes, &per_layer);
                last = per_layer;
            }
            let (got, _) = server.pull(&nodes, false);
            for l in 0..*layers {
                prop_assert!(
                    got[l] == last[l],
                    "layer {l}: pulled rows differ from last push"
                );
            }
            prop_assert_eq!(server.stored_nodes(), nodes.len());
            Ok(())
        },
    );
}

#[test]
fn prop_cache_coherent_after_pull() {
    check(
        "cache-coherence",
        30,
        |g| {
            let n_remote = 1 + g.int_scaled(0, 150);
            let hidden = 1 + g.int(0, 7);
            let pulls = 1 + g.int(0, 3);
            let seed = g.int(0, 9999) as u64;
            (n_remote, hidden, pulls, seed)
        },
        |(n_remote, hidden, pulls, seed)| {
            let server = EmbeddingServer::new(2, *hidden, NetConfig::default());
            let globals: Vec<u32> = (0..*n_remote as u32).collect();
            let rows: Vec<f32> = globals
                .iter()
                .flat_map(|&nd| (0..*hidden).map(move |j| nd as f32 * 10.0 + j as f32))
                .collect();
            server.push(&globals, &[rows.clone(), rows.clone()]);
            let mut cache = EmbCache::new(2, *hidden, *n_remote);
            let mut rng = optimes::util::rng::Rng::new(*seed, 1);
            for _ in 0..*pulls {
                let take = 1 + rng.below(*n_remote);
                let idxs: Vec<u32> = rng
                    .sample_indices(*n_remote, take)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                let gl: Vec<u32> = idxs.iter().map(|&i| globals[i as usize]).collect();
                let (per_layer, _) = server.pull(&gl, true);
                cache.insert(&idxs, &per_layer);
                // coherence: every pulled idx present with the exact row
                for (pos, &i) in idxs.iter().enumerate() {
                    prop_assert!(cache.is_present(i), "idx {i} missing after pull");
                    let want: Vec<f32> = (0..*hidden)
                        .map(|j| globals[i as usize] as f32 * 10.0 + j as f32)
                        .collect();
                    prop_assert!(
                        cache.row(1, i) == &want[..],
                        "cache row mismatch at idx {i} (pos {pos})"
                    );
                }
                prop_assert!(cache.missing_of(&idxs).is_empty(), "missing after insert");
            }
            Ok(())
        },
    );
}

/// Random explicit map: every bucket gets `replicas + 1` distinct owners
/// drawn by shuffling the backend set.
fn random_map(g: &mut Gen, n_backends: usize, replicas: usize, buckets: usize) -> ShardMap {
    let owners: Vec<Vec<u32>> = (0..buckets)
        .map(|_| {
            let mut ids: Vec<u32> = (0..n_backends as u32).collect();
            g.rng.shuffle(&mut ids);
            ids.truncate(replicas + 1);
            ids
        })
        .collect();
    ShardMap::from_owners(owners, n_backends).expect("random owner sets are valid")
}

#[test]
fn prop_shardmap_routing_is_total_and_replicas_disjoint() {
    check(
        "shardmap-routing-total",
        40,
        |g| {
            let n = 1 + g.int(0, 7);
            let r = g.int(0, n - 1);
            let buckets = 1 + g.int_scaled(0, 127);
            let uniform = g.bool();
            let map = if uniform {
                ShardMap::uniform(n, r).expect("r < n")
            } else {
                random_map(g, n, r, buckets)
            };
            let ids: Vec<u32> = (0..64).map(|_| g.int(0, 5_000_000) as u32).collect();
            (map, ids, n, r)
        },
        |(map, ids, n, r)| {
            for &id in ids {
                let bucket = map.bucket_of(id);
                prop_assert!(bucket < map.n_buckets(), "bucket {bucket} out of range");
                let owners = map.owners_of(id);
                prop_assert_eq!(owners, map.owners_of_bucket(bucket));
                prop_assert_eq!(owners.len(), *r + 1);
                prop_assert_eq!(owners[0] as usize, map.primary_of(id));
                for (k, &o) in owners.iter().enumerate() {
                    prop_assert!((o as usize) < *n, "owner {o} out of range");
                    prop_assert!(
                        !owners[..k].contains(&o),
                        "id {id}: backend {o} owns twice"
                    );
                }
                prop_assert!(
                    !map.replicas_of(id).contains(&owners[0]),
                    "id {id}: replica set aliases the primary"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rebalance_moves_exactly_the_changed_rows() {
    check(
        "rebalance-moves-owner-changes",
        12,
        |g| {
            let n = 2 + g.int(0, 3); // 2..=5 backends
            let r = g.int(0, n - 1);
            let buckets = 8 + g.int(0, 24);
            let map_a = random_map(g, n, r, buckets);
            let map_b = random_map(g, n, r, buckets);
            let n_ids = 1 + g.int_scaled(0, 300);
            let ids: Vec<u32> = (0..n_ids as u32).map(|i| i * 3 + 1).collect();
            (n, map_a, map_b, ids)
        },
        |(n, map_a, map_b, ids)| {
            let hidden = 4;
            let backends: Vec<Arc<dyn EmbeddingStore>> = (0..*n)
                .map(|_| {
                    Arc::new(EmbeddingServer::new(2, hidden, NetConfig::default()))
                        as Arc<dyn EmbeddingStore>
                })
                .collect();
            let store = ShardedStore::with_map(backends.clone(), map_a.clone())
                .map_err(|e| format!("with_map: {e:#}"))?;
            let row = |id: u32, l: usize| -> Vec<f32> {
                (0..hidden).map(|j| id as f32 * 7.0 + l as f32 + j as f32 * 0.5).collect()
            };
            let per_layer: Vec<Vec<f32>> = (0..2)
                .map(|l| ids.iter().flat_map(|&id| row(id, l)).collect())
                .collect();
            store.push(ids, &per_layer).map_err(|e| format!("push: {e:#}"))?;
            let before = store.stats().map_err(|e| format!("stats: {e:#}"))?;

            let report = store
                .rebalance(map_b.clone())
                .map_err(|e| format!("rebalance: {e:#}"))?;
            let after = store.stats().map_err(|e| format!("stats: {e:#}"))?;

            // no row lost, no row double-counted
            prop_assert_eq!(before.nodes, after.nodes);
            prop_assert_eq!(before.rows, after.rows);
            prop_assert_eq!(after.epoch, 1);

            // the report covers exactly the buckets whose owner set
            // changed, and copies exactly occupancy × added-owners rows
            let changed = map_a.changed_buckets(map_b);
            prop_assert_eq!(report.buckets_changed, changed.len());
            let mut expected_copied = 0usize;
            for &b in &changed {
                let occupancy = ids.iter().filter(|&&id| map_a.bucket_of(id) == b).count();
                let added = map_b
                    .owners_of_bucket(b)
                    .iter()
                    .filter(|o| !map_a.owners_of_bucket(b).contains(o))
                    .count();
                expected_copied += occupancy * added;
            }
            prop_assert_eq!(report.rows_copied, expected_copied);

            // a bucket is in the changed set iff its owner set differs
            for &id in ids.iter() {
                let a_owners = map_a.owners_of(id);
                let b_owners = map_b.owners_of(id);
                let set_changed = !(a_owners.len() == b_owners.len()
                    && a_owners.iter().all(|o| b_owners.contains(o)));
                prop_assert_eq!(changed.contains(&map_a.bucket_of(id)), set_changed);
            }

            // every row is now readable through the router AND resident
            // on every owner of the new map, with its original values
            for &id in ids.iter() {
                let (got, _) = store.pull(&[id], false).map_err(|e| format!("pull: {e:#}"))?;
                prop_assert!(got[0] == row(id, 0), "router lost row {id}");
                for &owner in map_b.owners_of(id) {
                    let (copy, _) = backends[owner as usize]
                        .pull(&[id], false)
                        .map_err(|e| format!("backend pull: {e:#}"))?;
                    prop_assert!(
                        copy[0] == row(id, 0) && copy[1] == row(id, 1),
                        "row {id} missing or stale on new owner {owner}"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_codec_roundtrips_respect_their_error_contracts() {
    check(
        "codec-roundtrip-bounds",
        30,
        |g| {
            let hidden = 1 + g.int(0, 31);
            let n = 1 + g.int(0, 15);
            // magnitudes spanning 1e-3 .. 1e3 (comfortably inside the
            // f16 normal range once multiplied by a unit uniform)
            let scale = 10f64.powi(g.int(0, 6) as i32 - 3) as f32;
            let rows: Vec<f32> = (0..n * hidden)
                .map(|_| ((g.f64() - 0.5) * 2.0) as f32 * scale)
                .collect();
            let k = 1 + g.int(0, 7);
            (hidden, n, rows, k)
        },
        |(hidden, n, rows, k)| {
            let (hidden, n) = (*hidden, *n);
            let mut bytes = Vec::new();
            let mut out = Vec::new();

            // raw: bit-exact, always
            let raw = CodecKind::Raw.build();
            raw.encode_rows(rows, hidden, &mut bytes);
            raw.decode_rows(&bytes, n, hidden, &mut out)
                .map_err(|e| format!("raw decode: {e:#}"))?;
            prop_assert!(
                rows.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits()),
                "raw codec is not bit-exact"
            );

            // f16 / bf16: bounded error + idempotence (a second trip is
            // bit-exact, so the push→pull double round-trip settles)
            for (kind, rel, abs) in [
                (CodecKind::F16, 1.0f32 / 1024.0, 1e-7f32),
                (CodecKind::Bf16, 1.0f32 / 128.0, 1e-30f32),
            ] {
                let c = kind.build();
                c.encode_rows(rows, hidden, &mut bytes);
                c.decode_rows(&bytes, n, hidden, &mut out)
                    .map_err(|e| format!("decode: {e:#}"))?;
                for (a, b) in rows.iter().zip(&out) {
                    prop_assert!(
                        (a - b).abs() <= a.abs() * rel + abs,
                        "{}: {a} decoded as {b}",
                        c.name()
                    );
                }
                let mut bytes2 = Vec::new();
                c.encode_rows(&out, hidden, &mut bytes2);
                prop_assert!(bytes == bytes2, "{} re-encode is not idempotent", c.name());
            }

            // int8: per-row affine bound (max − min)/510, plus fp slack
            let c = CodecKind::Int8.build();
            c.encode_rows(rows, hidden, &mut bytes);
            c.decode_rows(&bytes, n, hidden, &mut out)
                .map_err(|e| format!("int8 decode: {e:#}"))?;
            for (row, dec) in rows.chunks_exact(hidden).zip(out.chunks_exact(hidden)) {
                let lo = row.iter().copied().fold(f32::INFINITY, f32::min);
                let hi = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let span = hi - lo;
                let bound = span / 510.0 * 1.01 + (lo.abs() + span) * 1e-5 + 1e-12;
                for (a, b) in row.iter().zip(dec) {
                    prop_assert!(
                        (a - b).abs() <= bound,
                        "int8: {a} decoded as {b} (row span {span}, bound {bound})"
                    );
                }
            }

            // topk: exactly the K largest magnitudes survive, verbatim;
            // everything else decodes to zero
            let c = CodecKind::TopK(*k).build();
            c.encode_rows(rows, hidden, &mut bytes);
            c.decode_rows(&bytes, n, hidden, &mut out)
                .map_err(|e| format!("topk decode: {e:#}"))?;
            let k_eff = (*k).min(hidden);
            for (row, dec) in rows.chunks_exact(hidden).zip(out.chunks_exact(hidden)) {
                let kept: Vec<usize> = (0..hidden).filter(|&j| dec[j] != 0.0).collect();
                prop_assert!(kept.len() <= k_eff, "kept {} > K {k_eff}", kept.len());
                let min_kept = kept
                    .iter()
                    .map(|&j| row[j].abs())
                    .fold(f32::INFINITY, f32::min);
                for j in 0..hidden {
                    if dec[j] != 0.0 {
                        prop_assert!(
                            dec[j].to_bits() == row[j].to_bits(),
                            "topk altered a kept value"
                        );
                    } else {
                        // dropped (or genuinely zero): magnitude never
                        // exceeds the smallest kept one
                        prop_assert!(
                            kept.len() < k_eff || row[j].abs() <= min_kept,
                            "topk dropped |{}| while keeping min |{min_kept}|",
                            row[j]
                        );
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_delta_replays_converge_through_faults_and_rebalance() {
    check(
        "delta-converges",
        10,
        |g| {
            let n_nodes = 8 + g.int_scaled(0, 120);
            let writes = 3 + g.int(0, 4);
            let seed = g.int(0, 99_999) as u64;
            (n_nodes, writes, seed)
        },
        |(n_nodes, writes, seed)| {
            let h = 4;
            // reference: a plain server receiving every push in full
            let reference = EmbeddingServer::new(2, h, NetConfig::default());
            // subject: exact delta over a replicated sharded plane with
            // scripted shard blackouts and repair rebalances between
            // writes — the replay must converge to the same rows
            let mut handles = Vec::new();
            let backends: Vec<Arc<dyn EmbeddingStore>> = (0..3)
                .map(|i| {
                    let slab: Arc<dyn EmbeddingStore> =
                        Arc::new(EmbeddingServer::new(2, h, NetConfig::default()));
                    let faulted = FaultStore::new(slab, format!("shard{i}"), Vec::new());
                    handles.push(faulted.handle());
                    Arc::new(faulted) as Arc<dyn EmbeddingStore>
                })
                .collect();
            let sharded = Arc::new(
                ShardedStore::replicated(backends, 1).map_err(|e| format!("{e:#}"))?,
            );
            let delta = DeltaStore::new(Arc::clone(&sharded) as Arc<dyn EmbeddingStore>, 0.0);

            let nodes: Vec<u32> = (0..*n_nodes as u32).collect();
            let mut rng = optimes::util::rng::Rng::new(*seed, 3);
            let mut vals: Vec<f32> = nodes.iter().map(|&nd| nd as f32).collect();
            for w in 0..*writes {
                // mutate a random subset, leave the rest bit-identical
                // (node 0 never mutates, so every cached-epoch push has
                // at least one row to skip — deterministically)
                for v in vals.iter_mut().skip(1) {
                    if rng.chance(0.4) {
                        *v += (w + 1) as f32 * 0.5;
                    }
                }
                let layer: Vec<f32> = vals
                    .iter()
                    .flat_map(|&v| (0..h).map(move |j| v + j as f32))
                    .collect();
                reference.push(&nodes, &[layer.clone(), layer.clone()]);
                // even writes land during a single-shard blackout (the
                // R=1 budget absorbs it); odd writes are followed by a
                // same-map rebalance that repairs the quarantine before
                // the next shard dies — and bumps the epoch, forcing
                // the delta layer to resync in full
                let dead = w % 3;
                if w % 2 == 0 {
                    handles[dead].set_blackout(true);
                }
                delta
                    .push(&nodes, &[layer.clone(), layer.clone()])
                    .map_err(|e| format!("delta push {w}: {e:#}"))?;
                handles[dead].set_blackout(false);
                if w % 2 == 1 {
                    sharded
                        .rebalance(sharded.map())
                        .map_err(|e| format!("repair rebalance {w}: {e:#}"))?;
                }
            }
            // final repair so every owner is readable again
            sharded.rebalance(sharded.map()).map_err(|e| format!("{e:#}"))?;

            let (want, _) = reference.pull(&nodes, false);
            let (got, _) = delta.pull(&nodes, false).map_err(|e| format!("{e:#}"))?;
            prop_assert!(want == got, "delta replay diverged from full pushes");
            // node 0 never changed after the first push, so the second
            // write (whose cache epoch is still valid) must have
            // skipped it
            prop_assert!(delta.rows_skipped() > 0, "delta never skipped a row");
            Ok(())
        },
    );
}

#[test]
fn prop_round_policy_invariants() {
    check(
        "round-policy-invariants",
        60,
        |g| {
            let n = 1 + g.int(0, 15);
            let delays: Vec<f64> = (0..n).map(|_| g.f64() * 10.0).collect();
            // k deliberately ranges past n to exercise the clamp
            let k = 1 + g.int(0, n + 2);
            let slack = g.f64() * 0.5;
            let budget = g.f64() * 10.0;
            (delays, k, slack, budget)
        },
        |(delays, k, slack, budget)| {
            let n = delays.len();
            let mut sorted = delays.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let (t_min, t_max) = (sorted[0], sorted[n - 1]);

            // sync: everyone on time, barrier at the slowest report
            let p = Synchronous.plan(delays);
            prop_assert_eq!(p.n_on_time(), n);
            prop_assert!(p.release == t_max, "sync release {} != t_max {t_max}", p.release);
            prop_assert!(p.quorum_wait == 0.0);

            // quorum: at least min(k, n) on time, release bounded by the
            // k-th report plus slack and by the slowest report, and the
            // consumed wait never exceeds the slack window
            let k_eff = (*k).min(n);
            let t_k = sorted[k_eff - 1];
            let p = Quorum { k: *k, slack: *slack }.plan(delays);
            prop_assert!(
                p.n_on_time() >= k_eff,
                "quorum released with {} < k_eff {k_eff} on time",
                p.n_on_time()
            );
            prop_assert!(p.release >= t_k, "released before the quorum formed");
            prop_assert!(p.release <= t_k + slack + 1e-12, "release overshot the slack");
            prop_assert!(p.release <= t_max, "release past the slowest client");
            prop_assert!(
                p.quorum_wait >= 0.0 && p.quorum_wait <= slack + 1e-12,
                "quorum_wait {} outside [0, {slack}]",
                p.quorum_wait
            );
            for (i, &d) in delays.iter().enumerate() {
                prop_assert_eq!(p.on_time[i], d <= p.release);
            }

            // deadline: at least one client always makes it, release
            // clamped to [t_min, t_max]
            let p = Deadline { budget: *budget }.plan(delays);
            prop_assert!(p.n_on_time() >= 1, "deadline released an empty round");
            prop_assert!(p.release >= t_min && p.release <= t_max);
            prop_assert!(p.quorum_wait == 0.0);
            Ok(())
        },
    );
}

#[test]
fn prop_sync_equals_quorum_k_n() {
    check(
        "sync-equals-quorum-k-n",
        60,
        |g| {
            let n = 1 + g.int(0, 15);
            let delays: Vec<f64> = (0..n).map(|_| g.f64() * 10.0).collect();
            (delays,)
        },
        |(delays,)| {
            let sync = Synchronous.plan(delays);
            let quorum = Quorum { k: delays.len(), slack: 0.0 }.plan(delays);
            prop_assert_eq!(&sync, &quorum);
            Ok(())
        },
    );
}

#[test]
fn prop_staleness_weights_decay_monotonically() {
    check(
        "staleness-weights-decay",
        60,
        |g| {
            // decay in (0, 1]
            let decay = (g.f64() * 0.999 + 0.001).min(1.0);
            (decay,)
        },
        |&(decay,)| {
            let weights: Vec<f64> = (0..=10usize).map(|s| staleness_weight(s, decay)).collect();
            prop_assert!(weights[0] == 1.0, "fresh updates must weigh 1.0");
            for (s, w) in weights.iter().enumerate() {
                prop_assert!(
                    *w > 0.0 && *w <= 1.0,
                    "weight {w} at staleness {s} outside (0, 1]"
                );
            }
            for pair in weights.windows(2) {
                prop_assert!(pair[1] <= pair[0], "weights not monotone non-increasing");
            }
            Ok(())
        },
    );
}

/// Disjoint-total-cover: every vertex is assigned to exactly one
/// partition, and that partition belongs to an active client.
fn assert_cover(part: &optimes::graph::Partition, n: usize, active: &[usize]) -> Result<(), String> {
    prop_assert_eq!(part.assign.len(), n);
    prop_assert_eq!(part.sizes().iter().sum::<usize>(), n);
    for (v, &p) in part.assign.iter().enumerate() {
        prop_assert!(
            active.contains(&(p as usize)),
            "vertex {v} assigned to inactive partition {p}"
        );
    }
    Ok(())
}

#[test]
fn prop_depart_moves_only_the_departed_partition() {
    check(
        "depart-moves-only-departed",
        20,
        |g| {
            let graph = random_graph(g);
            let k = 2 + g.int(0, 4);
            let seed = g.int(0, 9999) as u64;
            let victim = g.int(0, k - 1);
            (graph, k, seed, victim)
        },
        |(graph, k, seed, victim)| {
            let mut part = metis_lite(graph, *k, *seed);
            let before = part.assign.clone();
            let mut mem = Membership::new(*k);
            let change = mem
                .record_leave(graph, &mut part, 0, *victim)
                .map_err(|e| format!("{e:#}"))?
                .clone();
            assert_cover(&part, graph.n, mem.active())?;
            prop_assert!(!mem.is_active(*victim), "departed client still active");
            for (v, (&old, &new)) in before.iter().zip(&part.assign).enumerate() {
                if old as usize == *victim {
                    prop_assert!(new as usize != *victim, "vertex {v} left behind");
                    prop_assert!(
                        change.moved.contains(&(v as u32, old, new)),
                        "move of vertex {v} not in the ledger"
                    );
                } else {
                    prop_assert!(old == new, "untouched vertex {v} moved ({old} -> {new})");
                }
            }
            prop_assert_eq!(
                change.moved.len(),
                before.iter().filter(|&&p| p as usize == *victim).count()
            );
            Ok(())
        },
    );
}

#[test]
fn prop_join_splits_only_the_heaviest_partition() {
    check(
        "join-splits-only-heaviest",
        20,
        |g| {
            let graph = random_graph(g);
            let k = 2 + g.int(0, 4);
            let seed = g.int(0, 9999) as u64;
            (graph, k, seed)
        },
        |(graph, k, seed)| {
            let mut part = metis_lite(graph, *k, *seed);
            let before = part.assign.clone();
            let sizes = part.sizes();
            // first-maximal partition — join_split's own tie-break
            let mut heavy = 0usize;
            for p in 1..*k {
                if sizes[p] > sizes[heavy] {
                    heavy = p;
                }
            }
            let mut mem = Membership::new(*k);
            let change = mem
                .record_join(graph, &mut part, 0)
                .map_err(|e| format!("{e:#}"))?
                .clone();
            prop_assert_eq!(change.client(), *k);
            prop_assert_eq!(part.k, *k + 1);
            assert_cover(&part, graph.n, mem.active())?;
            // exactly half the heaviest partition moved, nothing else
            prop_assert_eq!(change.moved.len(), sizes[heavy] / 2);
            for &(v, from, to) in &change.moved {
                prop_assert_eq!(from as usize, heavy);
                prop_assert_eq!(to as usize, *k);
                prop_assert_eq!(before[v as usize], from);
            }
            for (v, (&old, &new)) in before.iter().zip(&part.assign).enumerate() {
                if old != new {
                    prop_assert!(
                        change.moved.contains(&(v as u32, old, new)),
                        "vertex {v} moved outside the ledger"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ledger_apply_and_revert_round_trip() {
    check(
        "ledger-apply-revert",
        15,
        |g| {
            let graph = random_graph(g);
            let k = 2 + g.int(0, 3);
            let seed = g.int(0, 9999) as u64;
            let script: Vec<u32> = (0..3 + g.int(0, 3)).map(|_| g.int(0, 999) as u32).collect();
            (graph, k, seed, script)
        },
        |(graph, k, seed, script)| {
            let mut part = metis_lite(graph, *k, *seed);
            let original = part.assign.clone();
            let mut mem = Membership::new(*k);
            // random join/leave walk that never strands the session
            for (round, &pick) in script.iter().enumerate() {
                if pick % 2 == 0 || mem.active().len() < 2 {
                    mem.record_join(graph, &mut part, round).map_err(|e| format!("{e:#}"))?;
                } else {
                    let victim = mem.active()[pick as usize % mem.active().len()];
                    mem.record_leave(graph, &mut part, round, victim)
                        .map_err(|e| format!("{e:#}"))?;
                }
            }
            assert_cover(&part, graph.n, mem.active())?;

            // replaying the ledger on a fresh copy reproduces the state
            let mut replay = optimes::graph::Partition { k: *k, assign: original.clone() };
            let mut mem2 = Membership::new(*k);
            for change in mem.ledger().to_vec() {
                mem2.apply(&mut replay, change);
            }
            prop_assert_eq!(&replay.assign, &part.assign);
            prop_assert_eq!(replay.k, part.k);
            prop_assert_eq!(mem2.active(), mem.active());

            // reverting everything restores the original bit-for-bit
            while mem.revert_last(&mut part).is_some() {}
            prop_assert_eq!(&part.assign, &original);
            prop_assert_eq!(part.k, *k);
            prop_assert_eq!(mem.active(), &(0..*k).collect::<Vec<_>>()[..]);
            Ok(())
        },
    );
}

#[test]
fn prop_netsim_monotone() {
    check(
        "netsim-monotone",
        50,
        |g| {
            let a = g.int_scaled(0, 1_000_000);
            let b = g.int_scaled(0, 1_000_000);
            (a, b)
        },
        |&(a, b)| {
            let n = NetConfig::default();
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            prop_assert!(
                n.time_for_bytes(lo) <= n.time_for_bytes(hi),
                "cost model not monotone"
            );
            prop_assert!(n.time_for_bytes(lo) >= n.latency, "below latency floor");
            Ok(())
        },
    );
}
