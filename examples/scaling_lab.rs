//! Scaling lab: how OptimES behaves as the federation grows (the paper's
//! §5.7 study) — client counts 4/6/8 on the scaled Products graph, with
//! the per-phase breakdown showing where the time goes at each scale.
//!
//! ```bash
//! cargo run --release --example scaling_lab [--dataset products-s] [--rounds 10]
//! ```

use std::sync::Arc;

use optimes::coordinator::{run_session, SessionConfig, Strategy};
use optimes::harness;
use optimes::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let dataset = args.str_or("dataset", "products-s").to_string();
    let rounds = args.usize_or("rounds", 10);
    let (preset, graph) = harness::load_dataset(&dataset)?;
    let engine = harness::make_engine(optimes::runtime::ModelKind::Gc, 5)?;

    println!("scaling {} across federations of 4/6/8 clients ({rounds} rounds each)\n", dataset);
    println!(
        "{:>8} {:>7} | {:>9} {:>9} | {:>7} {:>7} {:>7} {:>7}",
        "clients", "strat", "peak acc", "round(s)", "pull", "train", "dyn", "push"
    );
    for clients in [4usize, 6, 8] {
        for strategy in [Strategy::e(), Strategy::opp()] {
            let cfg = SessionConfig {
                dataset: dataset.clone(),
                clients,
                strategy,
                rounds,
                epochs: 3,
                lr: 0.01,
                epoch_batches: preset.epoch_batches,
                eval_batches: 12,
                seed: 21,
                ..Default::default()
            };
            let m = run_session(&graph, &cfg, Arc::clone(&engine))?;
            let p = m.median_phases();
            println!(
                "{:>8} {:>7} | {:>8.2}% {:>8.3}s | {:>7.3} {:>7.3} {:>7.3} {:>7.3}",
                clients,
                m.strategy,
                m.peak_accuracy() * 100.0,
                m.median_round_time(),
                p.pull,
                p.train,
                p.dyn_pull,
                p.push
            );
        }
    }
    println!("\nas in the paper §5.7: smaller per-client subgraphs -> cheaper rounds but\nmore rounds to converge; the OptimES ordering is preserved at every scale.");
    Ok(())
}
