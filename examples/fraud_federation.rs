//! Cross-silo fraud-detection scenario (the paper's motivating workload,
//! §1: "banks hosting their transaction graph on a fintech cloud may wish
//! to build a common fraud model without revealing their graphs").
//!
//! Six "banks" each hold a shard of a transaction graph. The label task is
//! account-risk classification; cross-bank edges (inter-bank transfers)
//! are exactly the cross-client dependencies OptimES optimizes. This
//! example compares the default federated GNN (D — drop inter-bank
//! edges), EmbC (E), and OptimES (OPP) on time-to-accuracy, then prints a
//! per-bank boundary profile.
//!
//! ```bash
//! cargo run --release --example fraud_federation
//! ```

use std::sync::Arc;

use optimes::coordinator::metrics::paper_target_accuracy;
use optimes::coordinator::{run_session, SessionConfig, SessionMetrics, Strategy};
use optimes::graph::generate::{generate, GenParams};
use optimes::graph::partition::metis_lite;
use optimes::graph::subgraph::{build_all, Prune};
use optimes::harness;

fn main() -> anyhow::Result<()> {
    const BANKS: usize = 6;
    // A transaction-graph-flavoured synthetic: dense-ish, strongly
    // community-structured (each community = a regional customer
    // cluster), with weak account features — risk is mostly a
    // neighbourhood property, which is what makes dropping inter-bank
    // edges costly.
    let graph = generate(&GenParams {
        n: 12_000,
        avg_degree: 18.0,
        communities: 48,
        classes: 16,
        feat_dim: 32,
        homophily: 0.72,
        hub_alpha: 1.7,
        signal: 0.45,
        community_bias: 0.5,
        train_frac: 0.4,
        test_frac: 0.15,
        seed: 0xF4A0D,
    });

    // Boundary profile: what each bank would exchange.
    let part = metis_lite(&graph, BANKS, 7);
    let subs = build_all(&graph, &part, &Prune::None, 7);
    println!("bank boundary profile ({} accounts total):", graph.n);
    for s in &subs {
        println!(
            "  bank {}: {:>5} accounts, {:>4} inter-bank in-neighbours, {:>4} accounts referenced by other banks",
            s.client_id,
            s.n_local(),
            s.n_remote(),
            s.push_nodes.len()
        );
    }

    let engine = harness::make_engine(optimes::runtime::ModelKind::Gc, 5)?;
    let mut sessions: Vec<SessionMetrics> = Vec::new();
    for strategy in [Strategy::d(), Strategy::e(), Strategy::opp()] {
        let cfg = SessionConfig {
            dataset: "fraud-txn".into(),
            clients: BANKS,
            strategy,
            rounds: 14,
            epochs: 3,
            lr: 0.01,
            epoch_batches: 10,
            eval_batches: 16,
            seed: 11,
            ..Default::default()
        };
        let m = run_session(&graph, &cfg, Arc::clone(&engine))?;
        println!(
            "\n{:4}: peak risk-model accuracy {:.2}%, median round {:.3}s",
            m.strategy,
            m.peak_accuracy() * 100.0,
            m.median_round_time()
        );
        sessions.push(m);
    }

    let refs: Vec<&SessionMetrics> = sessions.iter().collect();
    let target = paper_target_accuracy(&refs);
    println!("\ntime-to-accuracy (target {:.1}%):", target * 100.0);
    for m in &sessions {
        println!(
            "  {:4}: {}",
            m.strategy,
            harness::fmt_opt_time(m.time_to_accuracy(target))
        );
    }
    Ok(())
}
