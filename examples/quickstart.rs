//! Quickstart: train a federated GNN with remote embeddings in ~40 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the scaled Reddit dataset, partitions it onto 4 clients, and runs
//! 12 federated rounds of the full OptimES strategy (OPP: push overlap +
//! uniform pruning + scored pull prefetch) through the composable session
//! API: a [`SessionBuilder`] wires the embedding store and a streaming
//! [`RoundObserver`], and per-round accuracy prints as it happens.
//!
//! To run the same session against a *remote* embedding store, start
//! `optimes serve --port 7070` in another terminal and pass
//! `.store(Arc::new(TcpEmbeddingStore::connect("127.0.0.1:7070", 2, 32)?))`
//! to the builder — the accuracy trajectory is identical.

use optimes::coordinator::{RoundMetrics, RoundObserver, SessionBuilder, SessionConfig, Strategy};
use optimes::harness;

/// Prints each round's accuracy and phase breakdown as it completes.
struct LivePrinter;

impl RoundObserver for LivePrinter {
    fn on_round(&mut self, r: &RoundMetrics) {
        let p = &r.mean_phases;
        println!(
            "round {:>2}: acc {:5.2}%  time {:.3}s  (pull {:.3} + train {:.3} + dyn {:.3} + push {:.3})",
            r.round,
            r.accuracy * 100.0,
            r.round_time,
            p.pull,
            p.train,
            p.dyn_pull,
            p.push
        );
    }
}

fn main() -> anyhow::Result<()> {
    // 1. dataset: a synthetic stand-in for Reddit (see DESIGN.md §3)
    let (preset, graph) = harness::load_dataset("reddit-s")?;

    // 2. compute engine: the AOT-compiled GraphConv artifacts via PJRT
    //    (falls back to the pure-Rust reference engine without artifacts)
    let engine = harness::make_engine(optimes::runtime::ModelKind::Gc, 5)?;

    // 3. federated session: 4 clients, OptimES "OPP" strategy
    let cfg = SessionConfig {
        dataset: preset.name.to_string(),
        clients: preset.default_clients,
        strategy: Strategy::opp(),
        rounds: 12,
        epochs: 3,
        lr: 0.01,
        epoch_batches: preset.epoch_batches,
        eval_batches: 16,
        ..Default::default()
    };
    println!(
        "training {} on {} clients with strategy {} ({} engine)...",
        preset.name,
        cfg.clients,
        cfg.strategy,
        harness::engine_kind()
    );
    let metrics = SessionBuilder::new(cfg)
        .observer(Box::new(LivePrinter))
        .build(&graph, engine)?
        .run()?;

    // 4. results
    println!(
        "\npeak accuracy {:.2}%  |  median round {:.3}s  |  {} embeddings at the {} store",
        metrics.peak_accuracy() * 100.0,
        metrics.median_round_time(),
        metrics.server_embeddings,
        metrics.store_backend
    );
    Ok(())
}
