"""Shared fixtures/helpers for the build-time (L1/L2) test suite."""

from __future__ import annotations

import numpy as np
import pytest

from compile.config import ModelConfig


def make_blocks(cfg: ModelConfig, rng: np.random.Generator, depth: int | None = None):
    """Random valid block tensors for a train/eval batch.

    Returns a dict with x/adjs/msks/rmasks/caches/labels/lmask matching the
    AOT contract for ``cfg`` (train/eval when depth==L, embed when L-1).
    """
    L = cfg.layers if depth is None else depth
    K = cfg.fanout
    sizes = [cfg.level_size(d) for d in range(L + 1)]
    if depth is not None and depth != cfg.layers:
        sizes = [cfg.embed_level_size(d) for d in range(L + 1)]
    x = rng.normal(size=(sizes[L], cfg.feat)).astype(np.float32)
    adjs, msks = [], []
    for d in range(L):
        adjs.append(rng.integers(0, sizes[d + 1], size=(sizes[d], K)).astype(np.int32))
        msks.append((rng.random(size=(sizes[d], K)) < 0.8).astype(np.float32))
    rmasks, caches = [], []
    n_sub = cfg.layers - 1 if depth is None else depth - 1
    for l in range(1, n_sub + 1):
        lvl = L - l
        rmasks.append((rng.random(size=(sizes[lvl],)) < 0.3).astype(np.float32))
        caches.append(rng.normal(size=(sizes[lvl], cfg.hidden)).astype(np.float32))
    labels = rng.integers(0, cfg.classes, size=(cfg.batch,)).astype(np.int32)
    lmask = np.ones((cfg.batch,), np.float32)
    return {
        "x": x,
        "adjs": adjs,
        "msks": msks,
        "rmasks": rmasks,
        "caches": caches,
        "labels": labels,
        "lmask": lmask,
    }


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


@pytest.fixture
def gc_cfg():
    return ModelConfig(model="gc", batch=4, fanout=3)


@pytest.fixture
def sage_cfg():
    return ModelConfig(model="sage", batch=4, fanout=3)
