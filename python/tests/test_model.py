"""L2 model semantics: forward, remote substitution, train/eval/embed."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import ModelConfig
from tests.conftest import make_blocks


def _forward(cfg, params, blocks, **kw):
    return model.forward(
        cfg,
        params,
        jnp.asarray(blocks["x"]),
        [jnp.asarray(a) for a in blocks["adjs"]],
        [jnp.asarray(m) for m in blocks["msks"]],
        [jnp.asarray(r) for r in blocks["rmasks"]],
        [jnp.asarray(c) for c in blocks["caches"]],
        **kw,
    )


@pytest.mark.parametrize("model_name", ["gc", "sage"])
def test_forward_shapes(rng, model_name):
    cfg = ModelConfig(model=model_name, batch=4, fanout=3)
    params = model.init_params(cfg, seed=0)
    blocks = make_blocks(cfg, rng)
    logits = _forward(cfg, params, blocks)
    assert logits.shape == (cfg.batch, cfg.classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_pallas_and_ref_paths_agree(rng, gc_cfg):
    params = model.init_params(gc_cfg, seed=0)
    blocks = make_blocks(gc_cfg, rng)
    a = _forward(gc_cfg, params, blocks, use_pallas=True)
    b = _forward(gc_cfg, params, blocks, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_remote_substitution_uses_cache(rng, gc_cfg):
    """A fully-remote hidden level must make logits depend only on caches."""
    cfg = gc_cfg
    params = model.init_params(cfg, seed=0)
    blocks = make_blocks(cfg, rng)
    # Mark ALL level-(L-1) rows (layer-1 outputs) and ALL level-(L-2) rows
    # remote: then x should not matter at all for the logits.
    for i in range(len(blocks["rmasks"])):
        blocks["rmasks"][i] = np.ones_like(blocks["rmasks"][i])
    base = _forward(cfg, params, blocks)
    blocks2 = dict(blocks)
    blocks2["x"] = blocks["x"] + 123.0
    got = _forward(cfg, params, blocks2)
    np.testing.assert_allclose(got, base, atol=1e-5)


def test_local_rows_ignore_cache(rng, gc_cfg):
    """With rmask == 0 the cache contents must be irrelevant."""
    cfg = gc_cfg
    params = model.init_params(cfg, seed=0)
    blocks = make_blocks(cfg, rng)
    for i in range(len(blocks["rmasks"])):
        blocks["rmasks"][i] = np.zeros_like(blocks["rmasks"][i])
    base = _forward(cfg, params, blocks)
    blocks["caches"] = [c + 1e3 for c in blocks["caches"]]
    got = _forward(cfg, params, blocks)
    np.testing.assert_allclose(got, base, atol=1e-5)


def _flat_train_args(cfg, params, m, v, t, lr, blocks):
    return (
        list(params)
        + list(m)
        + list(v)
        + [jnp.float32(t), jnp.float32(lr), jnp.asarray(blocks["x"])]
        + [jnp.asarray(a) for a in blocks["adjs"]]
        + [jnp.asarray(mk) for mk in blocks["msks"]]
        + [jnp.asarray(r) for r in blocks["rmasks"]]
        + [jnp.asarray(c) for c in blocks["caches"]]
        + [jnp.asarray(blocks["labels"]), jnp.asarray(blocks["lmask"])]
    )


@pytest.mark.parametrize("model_name", ["gc", "sage"])
def test_train_step_learns_fixed_batch(rng, model_name):
    """Adam on one fixed batch must drive the loss down hard."""
    cfg = ModelConfig(model=model_name, batch=8, fanout=2)
    params = model.init_params(cfg, seed=0)
    m = model.zeros_like_params(cfg)
    v = model.zeros_like_params(cfg)
    blocks = make_blocks(cfg, rng)
    train = model.make_train_fn(cfg)
    np_ = len(cfg.param_specs())
    first_loss, loss = None, None
    for t in range(1, 41):
        out = train(*_flat_train_args(cfg, params, m, v, t, 0.01, blocks))
        params, m, v = out[:np_], out[np_ : 2 * np_], out[2 * np_ : 3 * np_]
        loss = float(out[3 * np_])
        if first_loss is None:
            first_loss = loss
    assert loss < first_loss * 0.5, (first_loss, loss)


def test_eval_counts_and_masking(rng, gc_cfg):
    cfg = gc_cfg
    params = model.init_params(cfg, seed=0)
    blocks = make_blocks(cfg, rng)
    blocks["lmask"] = np.array([1, 1, 0, 0], np.float32)
    ev = model.make_eval_fn(cfg)
    np_ = len(cfg.param_specs())
    args = (
        list(params)
        + [jnp.asarray(blocks["x"])]
        + [jnp.asarray(a) for a in blocks["adjs"]]
        + [jnp.asarray(mk) for mk in blocks["msks"]]
        + [jnp.asarray(r) for r in blocks["rmasks"]]
        + [jnp.asarray(c) for c in blocks["caches"]]
        + [jnp.asarray(blocks["labels"]), jnp.asarray(blocks["lmask"])]
    )
    loss, correct, total = ev(*args)
    assert float(total) == 2.0
    assert 0.0 <= float(correct) <= 2.0
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("model_name", ["gc", "sage"])
def test_embed_matches_manual_forward(rng, model_name):
    """embed() must equal running depth L-1 of forward and slicing prefixes."""
    cfg = ModelConfig(model=model_name, batch=4, fanout=3, push_batch=6)
    params = model.init_params(cfg, seed=1)
    depth = cfg.layers - 1
    blocks = make_blocks(cfg, rng, depth=depth)
    emb = model.make_embed_fn(cfg)
    args = (
        list(params)
        + [jnp.asarray(blocks["x"])]
        + [jnp.asarray(a) for a in blocks["adjs"]]
        + [jnp.asarray(mk) for mk in blocks["msks"]]
        + [jnp.asarray(r) for r in blocks["rmasks"]]
        + [jnp.asarray(c) for c in blocks["caches"]]
    )
    outs = emb(*args)
    assert len(outs) == cfg.layers - 1
    _, hidden = model.forward(
        cfg,
        params,
        jnp.asarray(blocks["x"]),
        [jnp.asarray(a) for a in blocks["adjs"]],
        [jnp.asarray(mk) for mk in blocks["msks"]],
        [jnp.asarray(r) for r in blocks["rmasks"]],
        [jnp.asarray(c) for c in blocks["caches"]],
        depth=depth,
        collect_hidden=True,
    )
    for got, hl in zip(outs, hidden):
        np.testing.assert_allclose(got, hl[: cfg.push_batch], atol=1e-5)
        assert got.shape == (cfg.push_batch, cfg.hidden)


def test_masked_xent_uniform_logits(rng):
    logits = jnp.zeros((5, 4), jnp.float32)
    labels = jnp.asarray([0, 1, 2, 3, 0], jnp.int32)
    lmask = jnp.ones((5,), jnp.float32)
    loss, correct, total = model.masked_xent(logits, labels, lmask)
    np.testing.assert_allclose(float(loss), np.log(4.0), rtol=1e-6)
    assert float(total) == 5.0
