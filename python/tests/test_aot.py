"""AOT contract: the manifest specs must match what the functions accept
and produce, and the HLO lowering must parse.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.config import DEFAULT_CONFIGS, ModelConfig

SMALL = ModelConfig(model="gc", batch=2, fanout=2, push_batch=3)
SMALL_SAGE = ModelConfig(model="sage", batch=2, fanout=2, push_batch=3)

_DT = {"f32": jnp.float32, "i32": jnp.int32}


def _materialize(specs, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _, dt, shape in specs:
        if dt == "i32":
            out.append(jnp.asarray(rng.integers(0, 2, size=shape), jnp.int32))
        else:
            out.append(jnp.asarray(rng.normal(size=shape), jnp.float32))
    return out


@pytest.mark.parametrize("cfg", [SMALL, SMALL_SAGE])
@pytest.mark.parametrize("kind", ["train", "eval", "embed"])
def test_specs_match_function_arity_and_outputs(cfg, kind):
    make_fn, in_specs, out_specs = aot.ENTRYPOINT_SPECS[kind]
    fn = make_fn(cfg)
    args = _materialize(in_specs(cfg))
    outs = fn(*args)
    expected = out_specs(cfg)
    assert len(outs) == len(expected), (len(outs), len(expected))
    for o, (name, dt, shape) in zip(outs, expected):
        assert tuple(o.shape) == tuple(shape), (name, o.shape, shape)


@pytest.mark.parametrize("kind", ["train", "eval", "embed"])
def test_lowering_produces_hlo_text(kind):
    text = aot.lower_entrypoint(SMALL, kind)
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text


def test_manifest_on_disk_is_consistent():
    """If `make artifacts` has run, every manifest entry must be coherent."""
    mpath = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    names = set()
    for ep in manifest["entrypoints"]:
        assert ep["name"] not in names
        names.add(ep["name"])
        cfg = ModelConfig(
            model=ep["model"],
            layers=ep["config"]["layers"],
            feat=ep["config"]["feat"],
            hidden=ep["config"]["hidden"],
            classes=ep["config"]["classes"],
            batch=ep["config"]["batch"],
            fanout=ep["config"]["fanout"],
            push_batch=ep["config"]["push_batch"],
        )
        _, in_specs, out_specs = aot.ENTRYPOINT_SPECS[ep["kind"]]
        want_in = [
            {"name": n, "dtype": d, "shape": list(s)} for n, d, s in in_specs(cfg)
        ]
        want_out = [
            {"name": n, "dtype": d, "shape": list(s)} for n, d, s in out_specs(cfg)
        ]
        assert ep["inputs"] == want_in, ep["name"]
        assert ep["outputs"] == want_out, ep["name"]
        hlo = os.path.join(os.path.dirname(mpath), ep["file"])
        assert os.path.exists(hlo), hlo


def test_default_configs_have_unique_names():
    names = [c.name for c in DEFAULT_CONFIGS]
    assert len(names) == len(set(names))


def test_train_executes_under_jit_and_updates_params():
    cfg = SMALL
    make_fn, in_specs, _ = aot.ENTRYPOINT_SPECS["train"]
    fn = jax.jit(make_fn(cfg))
    args = _materialize(in_specs(cfg), seed=3)
    # overwrite optimizer state, t and lr with sane values (random negative
    # v would NaN under sqrt)
    np_params = len(cfg.param_specs())
    for i in range(np_params, 3 * np_params):
        args[i] = jnp.zeros_like(args[i])
    args[3 * np_params] = jnp.float32(1.0)  # t
    args[3 * np_params + 1] = jnp.float32(0.01)  # lr
    outs = fn(*args)
    loss = float(outs[3 * np_params])
    assert np.isfinite(loss)
    # params must move
    moved = any(
        float(jnp.abs(o - a).max()) > 0 for o, a in zip(outs[:np_params], args[:np_params])
    )
    assert moved
