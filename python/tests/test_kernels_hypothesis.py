"""Hypothesis sweep: Pallas kernel == oracle across shapes/masks/dtypes.

The system prompt for this reproduction mandates hypothesis-driven shape
sweeps for the L1 kernel; tolerances are fp32-tight because the kernel and
the oracle share op order.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_gc_layer, fused_sage_layer, ref

SETTINGS = settings(max_examples=30, deadline=None)


@st.composite
def layer_case(draw):
    n = draw(st.integers(min_value=1, max_value=160))
    k = draw(st.integers(min_value=1, max_value=12))
    d = draw(st.sampled_from([1, 4, 8, 16, 32]))
    h = draw(st.sampled_from([1, 8, 16, 32]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    p = draw(st.floats(min_value=0.0, max_value=1.0))
    activate = draw(st.booleans())
    return n, k, d, h, seed, p, activate


def _tensors(n, k, d, h, seed, p):
    rng = np.random.default_rng(seed)
    neigh = jnp.asarray(rng.normal(size=(n, k, d)), jnp.float32)
    selfx = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mask = jnp.asarray(rng.random(size=(n, k)) < p, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, h)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    return neigh, selfx, mask, w, b, rng


@SETTINGS
@given(layer_case())
def test_gc_kernel_equals_ref_swept(case):
    n, k, d, h, seed, p, activate = case
    neigh, selfx, mask, w, b, _ = _tensors(n, k, d, h, seed, p)
    got = fused_gc_layer(neigh, selfx, mask, w, b, activate)
    exp = ref.gc_layer(neigh, selfx, mask, w, b, activate)
    np.testing.assert_allclose(got, exp, atol=2e-5, rtol=1e-5)


@SETTINGS
@given(layer_case())
def test_sage_kernel_equals_ref_swept(case):
    n, k, d, h, seed, p, activate = case
    neigh, selfx, mask, w, b, rng = _tensors(n, k, d, h, seed, p)
    wn = jnp.asarray(rng.normal(size=(d, h)), jnp.float32)
    got = fused_sage_layer(neigh, selfx, mask, w, wn, b, activate)
    exp = ref.sage_layer(neigh, selfx, mask, w, wn, b, activate)
    np.testing.assert_allclose(got, exp, atol=2e-5, rtol=1e-5)
