"""L1 correctness: Pallas fused layers vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: forward equality
(same op order -> tight tolerance) and the hand-derived custom_vjp backward
vs ``jax.grad`` of the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import fused_gc_layer, fused_sage_layer, ref
from compile.kernels.agg_matmul import _pick_tile

ATOL = 1e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _mask(rng, n, k, p=0.7):
    return jnp.asarray((rng.random(size=(n, k)) < p), jnp.float32)


@pytest.mark.parametrize("activate", [True, False])
@pytest.mark.parametrize("n,k,d,h", [(64, 5, 32, 32), (96, 3, 16, 8), (7, 4, 8, 8)])
def test_gc_forward_matches_ref(rng_np, activate, n, k, d, h):
    rng = rng_np
    neigh, selfx = _rand(rng, n, k, d), _rand(rng, n, d)
    mask, w, b = _mask(rng, n, k), _rand(rng, d, h), _rand(rng, h)
    got = fused_gc_layer(neigh, selfx, mask, w, b, activate)
    exp = ref.gc_layer(neigh, selfx, mask, w, b, activate)
    np.testing.assert_allclose(got, exp, atol=ATOL)


@pytest.mark.parametrize("activate", [True, False])
@pytest.mark.parametrize("n,k,d,h", [(64, 5, 32, 32), (40, 2, 8, 16)])
def test_sage_forward_matches_ref(rng_np, activate, n, k, d, h):
    rng = rng_np
    neigh, selfx = _rand(rng, n, k, d), _rand(rng, n, d)
    mask = _mask(rng, n, k)
    ws, wn, b = _rand(rng, d, h), _rand(rng, d, h), _rand(rng, h)
    got = fused_sage_layer(neigh, selfx, mask, ws, wn, b, activate)
    exp = ref.sage_layer(neigh, selfx, mask, ws, wn, b, activate)
    np.testing.assert_allclose(got, exp, atol=ATOL)


def test_all_masked_row_aggregates_to_self_only(rng_np):
    """Rows with zero valid neighbours must reduce to act(self @ W + b)."""
    rng = rng_np
    n, k, d, h = 16, 5, 8, 8
    neigh, selfx = _rand(rng, n, k, d), _rand(rng, n, d)
    mask = jnp.zeros((n, k), jnp.float32)
    w, b = _rand(rng, d, h), _rand(rng, h)
    got = fused_gc_layer(neigh, selfx, mask, w, b, True)
    exp = jnp.maximum(selfx @ w + b[None, :], 0.0)
    np.testing.assert_allclose(got, exp, atol=ATOL)


def test_masked_slots_never_leak(rng_np):
    """Changing values in masked-out slots must not change the output."""
    rng = rng_np
    n, k, d, h = 32, 4, 8, 8
    neigh, selfx = _rand(rng, n, k, d), _rand(rng, n, d)
    mask = _mask(rng, n, k, p=0.5)
    w, b = _rand(rng, d, h), _rand(rng, h)
    base = fused_gc_layer(neigh, selfx, mask, w, b, True)
    poisoned = neigh + (1.0 - mask[:, :, None]) * 1e6
    got = fused_gc_layer(poisoned, selfx, mask, w, b, True)
    np.testing.assert_allclose(got, base, atol=1e-3)


@pytest.mark.parametrize("model", ["gc", "sage"])
def test_custom_vjp_matches_ref_grad(rng_np, model):
    rng = rng_np
    n, k, d, h = 48, 5, 16, 8
    neigh, selfx = _rand(rng, n, k, d), _rand(rng, n, d)
    mask = _mask(rng, n, k)
    cotan = _rand(rng, n, h)

    if model == "gc":
        w, b = _rand(rng, d, h), _rand(rng, h)

        def fk(ne, se, w_, b_):
            return jnp.sum(fused_gc_layer(ne, se, mask, w_, b_, True) * cotan)

        def fr(ne, se, w_, b_):
            return jnp.sum(ref.gc_layer(ne, se, mask, w_, b_, True) * cotan)

        args = (neigh, selfx, w, b)
        nd = 4
    else:
        ws, wn, b = _rand(rng, d, h), _rand(rng, d, h), _rand(rng, h)

        def fk(ne, se, a_, c_, b_):
            return jnp.sum(fused_sage_layer(ne, se, mask, a_, c_, b_, True) * cotan)

        def fr(ne, se, a_, c_, b_):
            return jnp.sum(ref.sage_layer(ne, se, mask, a_, c_, b_, True) * cotan)

        args = (neigh, selfx, ws, wn, b)
        nd = 5

    gk = jax.grad(fk, argnums=tuple(range(nd)))(*args)
    gr = jax.grad(fr, argnums=tuple(range(nd)))(*args)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(a, c, atol=5e-4)


def test_pick_tile_divides():
    for n in [1, 2, 7, 32, 64, 96, 1152, 6912, 968, 5324]:
        t = _pick_tile(n)
        assert n % t == 0 and 1 <= t <= 128


@pytest.fixture
def rng_np():
    return np.random.default_rng(7)
