"""Layer-2 JAX model: GraphConv / SAGEConv over padded neighbourhood blocks.

Three AOT entrypoints per :class:`~compile.config.ModelConfig` (see
``config.py`` for the block layout and the shape contract shared with the
Rust coordinator):

* ``train`` — one minibatch: forward (with remote-embedding substitution),
  masked softmax cross-entropy, backward, Adam update. Returns the updated
  parameters + optimizer state and (loss, correct, total).
* ``embed`` — compute ``h^1..h^{L-1}`` for a batch of push nodes from their
  (L-1)-hop sampled neighbourhood, using cached remote embeddings exactly
  like the training forward pass (paper §3.2.2 "push phase").
* ``eval``  — forward-only on a labelled batch; returns (loss, correct,
  total). Used by the aggregation server for global validation.

Every function here is pure and traceable; ``aot.py`` lowers them once to
HLO text. Python never runs on the request path.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import fused_gc_layer, fused_sage_layer, ref

Params = List[jnp.ndarray]

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# ---------------------------------------------------------------------------
# Parameter initialization (mirrored by rust RefEngine for cross-checks)
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Glorot-uniform weights, zero biases, in canonical flat order."""
    key = jax.random.PRNGKey(seed)
    params: Params = []
    for name, shape in cfg.param_specs():
        if len(shape) == 2:
            key, sub = jax.random.split(key)
            fan_in, fan_out = shape
            limit = (6.0 / (fan_in + fan_out)) ** 0.5
            params.append(
                jax.random.uniform(
                    sub, shape, jnp.float32, minval=-limit, maxval=limit
                )
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def zeros_like_params(cfg: ModelConfig) -> Params:
    return [jnp.zeros(shape, jnp.float32) for _, shape in cfg.param_specs()]


def _layer_params(cfg: ModelConfig, params: Params, l: int):
    """Slice the flat parameter list for 1-based layer ``l``."""
    per = 3 if cfg.model == "sage" else 2
    chunk = params[(l - 1) * per : l * per]
    return chunk


# ---------------------------------------------------------------------------
# Forward pass over nested level arrays
# ---------------------------------------------------------------------------


def _apply_layer(cfg, params, l, neigh, self_h, mask, activate, use_pallas):
    if cfg.model == "sage":
        ws, wn, b = _layer_params(cfg, params, l)
        if use_pallas:
            return fused_sage_layer(neigh, self_h, mask, ws, wn, b, activate)
        return ref.sage_layer(neigh, self_h, mask, ws, wn, b, activate)
    w, b = _layer_params(cfg, params, l)
    if use_pallas:
        return fused_gc_layer(neigh, self_h, mask, w, b, activate)
    return ref.gc_layer(neigh, self_h, mask, w, b, activate)


def forward(
    cfg: ModelConfig,
    params: Params,
    x: jnp.ndarray,
    adjs: Sequence[jnp.ndarray],
    msks: Sequence[jnp.ndarray],
    rmasks: Sequence[jnp.ndarray],
    caches: Sequence[jnp.ndarray],
    *,
    depth: int | None = None,
    use_pallas: bool = True,
    collect_hidden: bool = False,
):
    """Run ``depth`` GNN layers over nested level arrays.

    Args:
      x: ``[s_depth, F]`` h^0 features over the deepest level array.
      adjs: ``adjs[d]`` is ``[s_d, K]`` i32 indices of level-``d`` rows'
        sampled children inside level ``d+1``; ``d`` from 0 to depth-1.
      msks: matching ``[s_d, K]`` f32 validity masks.
      rmasks: for each hidden layer ``l`` (1-based, l < L), ``[s_{L'-l}]``
        remote flags at the level that layer outputs (``L'`` = depth).
      caches: matching ``[s_{L'-l}, H]`` cached remote embeddings ``h^l``.
      collect_hidden: also return the post-substitution hidden layers
        (used by ``embed``).

    Returns:
      ``[s_0, out_dim]`` output of the last applied layer (and the hidden
      list if requested).
    """
    depth = cfg.layers if depth is None else depth
    h = x
    hidden: List[jnp.ndarray] = []
    for l in range(1, depth + 1):
        lvl = depth - l  # level whose rows this layer produces
        s_lvl = adjs[lvl].shape[0]
        self_h = h[:s_lvl]
        neigh = jnp.take(h, adjs[lvl], axis=0)  # [s_lvl, K, D]
        activate = l < cfg.layers
        out = _apply_layer(
            cfg, params, l, neigh, self_h, msks[lvl], activate, use_pallas
        )
        if l - 1 < len(rmasks):
            # Remote rows at this level carry server-cached h^l embeddings;
            # their locally-computed value (from masked-out children and
            # zero features) is overridden (paper §3.2.2).
            r = rmasks[l - 1][:, None]
            out = (1.0 - r) * out + r * caches[l - 1]
        if collect_hidden:
            hidden.append(out)
        h = out
    if collect_hidden:
        return h, hidden
    return h


def masked_xent(
    logits: jnp.ndarray, labels: jnp.ndarray, lmask: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Masked mean softmax cross-entropy + correct count.

    Returns (loss, correct, total) — all f32 scalars.
    """
    ls = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(ls, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    total = jnp.sum(lmask)
    denom = jnp.maximum(total, 1.0)
    loss = -jnp.sum(picked * lmask) / denom
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.float32) * lmask)
    return loss, correct, total


# ---------------------------------------------------------------------------
# Entrypoint builders (flat positional signatures for AOT)
# ---------------------------------------------------------------------------


def train_arity(cfg: ModelConfig) -> Dict[str, int]:
    """Number of leading params/m/v arrays in the flat train signature."""
    return {"params": len(cfg.param_specs())}


def _split_train_args(cfg: ModelConfig, args):
    np_ = len(cfg.param_specs())
    it = iter(args)
    params = [next(it) for _ in range(np_)]
    m = [next(it) for _ in range(np_)]
    v = [next(it) for _ in range(np_)]
    t = next(it)
    lr = next(it)
    x = next(it)
    adjs = [next(it) for _ in range(cfg.layers)]
    msks = [next(it) for _ in range(cfg.layers)]
    rmasks = [next(it) for _ in range(cfg.layers - 1)]
    caches = [next(it) for _ in range(cfg.layers - 1)]
    labels = next(it)
    lmask = next(it)
    rest = list(it)
    assert not rest, f"{len(rest)} unexpected trailing args"
    return params, m, v, t, lr, x, adjs, msks, rmasks, caches, labels, lmask


def make_train_fn(cfg: ModelConfig, use_pallas: bool = True):
    """Flat-signature train step: forward + backward + Adam.

    Flat input order (see ``aot.py`` for the generated manifest):
      ``params..., m..., v..., t, lr, x, adj0..adj{L-1}, msk0..msk{L-1},
      rmask1..rmask{L-1}, cache1..cache{L-1}, labels, lmask``
    Flat outputs:
      ``params'..., m'..., v'..., loss, correct, total``
    """

    def train(*args):
        (params, m, v, t, lr, x, adjs, msks, rmasks, caches, labels, lmask) = (
            _split_train_args(cfg, args)
        )

        def loss_fn(ps):
            logits = forward(
                cfg, ps, x, adjs, msks, rmasks, caches, use_pallas=use_pallas
            )
            loss, correct, total = masked_xent(logits, labels, lmask)
            return loss, (correct, total)

        (loss, (correct, total)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params
        )
        # Adam with bias correction; t is the 1-based step counter.
        b1t = ADAM_B1**t
        b2t = ADAM_B2**t
        new_p, new_m, new_v = [], [], []
        for p, mi, vi, g in zip(params, m, v, grads):
            mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
            vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
            mhat = mi / (1.0 - b1t)
            vhat = vi / (1.0 - b2t)
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_p + new_m + new_v + [loss, correct, total])

    return train


def make_eval_fn(cfg: ModelConfig, use_pallas: bool = True):
    """Flat-signature forward-only evaluation.

    Inputs: ``params..., x, adj*, msk*, rmask*, cache*, labels, lmask``.
    Outputs: ``loss, correct, total``.
    """

    def evaluate(*args):
        np_ = len(cfg.param_specs())
        it = iter(args)
        params = [next(it) for _ in range(np_)]
        x = next(it)
        adjs = [next(it) for _ in range(cfg.layers)]
        msks = [next(it) for _ in range(cfg.layers)]
        rmasks = [next(it) for _ in range(cfg.layers - 1)]
        caches = [next(it) for _ in range(cfg.layers - 1)]
        labels = next(it)
        lmask = next(it)
        logits = forward(
            cfg, params, x, adjs, msks, rmasks, caches, use_pallas=use_pallas
        )
        loss, correct, total = masked_xent(logits, labels, lmask)
        return (loss, correct, total)

    return evaluate


def make_embed_fn(cfg: ModelConfig, use_pallas: bool = True):
    """Flat-signature push-embedding computation.

    Computes ``h^1..h^{L-1}`` for ``P = cfg.push_batch`` push nodes from
    their (L-1)-hop sampled neighbourhood. Remote neighbours encountered in
    that neighbourhood use the previous round's cached embeddings, exactly
    like training (paper §3.2.2: "the previous round's embeddings for the
    pull nodes are utilized to calculate the new embeddings of the push
    nodes").

    Inputs: ``params..., x, adj0..adj{L-2}, msk0..msk{L-2},
    rmask1..rmask{L-2}, cache1..cache{L-2}``  (for L=3: one rmask/cache at
    level 1 holding h^1 of remote rows).
    Outputs: ``h1 [P,H], ..., h{L-1} [P,H]``.
    """
    depth = cfg.layers - 1

    def embed(*args):
        np_ = len(cfg.param_specs())
        it = iter(args)
        params = [next(it) for _ in range(np_)]
        x = next(it)
        adjs = [next(it) for _ in range(depth)]
        msks = [next(it) for _ in range(depth)]
        rmasks = [next(it) for _ in range(depth - 1)]
        caches = [next(it) for _ in range(depth - 1)]
        _, hidden = forward(
            cfg,
            params,
            x,
            adjs,
            msks,
            rmasks,
            caches,
            depth=depth,
            use_pallas=use_pallas,
            collect_hidden=True,
        )
        p = cfg.push_batch
        # hidden[l-1] holds h^l over level_{depth-l}; the push rows are the
        # P-prefix of every level array.
        return tuple(hl[:p] for hl in hidden)

    return embed
