"""AOT pipeline: lower every (model x entrypoint x fanout) to HLO text.

Python runs ONCE at build time (``make artifacts``); the Rust coordinator
loads ``artifacts/*.hlo.txt`` via the PJRT C API and never calls back into
Python.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Alongside the HLO files we emit ``manifest.json`` describing, for each
entrypoint, the exact flat input/output order with dtypes and shapes —
the Rust side validates its marshaling against this file at startup.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .config import DEFAULT_CONFIGS, ModelConfig

Spec = Tuple[str, str, Tuple[int, ...]]  # (name, dtype, shape)

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Flat input/output specs per entrypoint (the manifest contract)
# ---------------------------------------------------------------------------


def _param_specs(cfg: ModelConfig, prefix: str = "") -> List[Spec]:
    return [(prefix + n, "f32", tuple(s)) for n, s in cfg.param_specs()]


def train_input_specs(cfg: ModelConfig) -> List[Spec]:
    L, K, B = cfg.layers, cfg.fanout, cfg.batch
    specs: List[Spec] = []
    specs += _param_specs(cfg)
    specs += _param_specs(cfg, "m_")
    specs += _param_specs(cfg, "v_")
    specs.append(("t", "f32", ()))
    specs.append(("lr", "f32", ()))
    specs.append(("x", "f32", (cfg.level_size(L), cfg.feat)))
    for d in range(L):
        specs.append((f"adj{d}", "i32", (cfg.level_size(d), K)))
    for d in range(L):
        specs.append((f"msk{d}", "f32", (cfg.level_size(d), K)))
    for l in range(1, L):
        specs.append((f"rmask{l}", "f32", (cfg.level_size(L - l),)))
    for l in range(1, L):
        specs.append((f"cache{l}", "f32", (cfg.level_size(L - l), cfg.hidden)))
    specs.append(("labels", "i32", (B,)))
    specs.append(("lmask", "f32", (B,)))
    return specs


def train_output_specs(cfg: ModelConfig) -> List[Spec]:
    specs: List[Spec] = []
    specs += _param_specs(cfg)
    specs += _param_specs(cfg, "m_")
    specs += _param_specs(cfg, "v_")
    specs.append(("loss", "f32", ()))
    specs.append(("correct", "f32", ()))
    specs.append(("total", "f32", ()))
    return specs


def eval_input_specs(cfg: ModelConfig) -> List[Spec]:
    L, K, B = cfg.layers, cfg.fanout, cfg.batch
    specs: List[Spec] = []
    specs += _param_specs(cfg)
    specs.append(("x", "f32", (cfg.level_size(L), cfg.feat)))
    for d in range(L):
        specs.append((f"adj{d}", "i32", (cfg.level_size(d), K)))
    for d in range(L):
        specs.append((f"msk{d}", "f32", (cfg.level_size(d), K)))
    for l in range(1, L):
        specs.append((f"rmask{l}", "f32", (cfg.level_size(L - l),)))
    for l in range(1, L):
        specs.append((f"cache{l}", "f32", (cfg.level_size(L - l), cfg.hidden)))
    specs.append(("labels", "i32", (B,)))
    specs.append(("lmask", "f32", (B,)))
    return specs


def eval_output_specs(cfg: ModelConfig) -> List[Spec]:
    return [("loss", "f32", ()), ("correct", "f32", ()), ("total", "f32", ())]


def embed_input_specs(cfg: ModelConfig) -> List[Spec]:
    depth, K = cfg.layers - 1, cfg.fanout
    specs: List[Spec] = []
    specs += _param_specs(cfg)
    specs.append(("x", "f32", (cfg.embed_level_size(depth), cfg.feat)))
    for d in range(depth):
        specs.append((f"adj{d}", "i32", (cfg.embed_level_size(d), K)))
    for d in range(depth):
        specs.append((f"msk{d}", "f32", (cfg.embed_level_size(d), K)))
    for l in range(1, depth):
        specs.append((f"rmask{l}", "f32", (cfg.embed_level_size(depth - l),)))
    for l in range(1, depth):
        specs.append(
            (f"cache{l}", "f32", (cfg.embed_level_size(depth - l), cfg.hidden))
        )
    return specs


def embed_output_specs(cfg: ModelConfig) -> List[Spec]:
    return [
        (f"h{l}", "f32", (cfg.push_batch, cfg.hidden))
        for l in range(1, cfg.layers)
    ]


ENTRYPOINT_SPECS: Dict[str, Tuple[Callable, Callable, Callable]] = {
    # kind -> (make_fn, input_specs, output_specs)
    "train": (model.make_train_fn, train_input_specs, train_output_specs),
    "eval": (model.make_eval_fn, eval_input_specs, eval_output_specs),
    "embed": (model.make_embed_fn, embed_input_specs, embed_output_specs),
}


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def lower_entrypoint(cfg: ModelConfig, kind: str, use_pallas: bool = True) -> str:
    make_fn, in_specs, _ = ENTRYPOINT_SPECS[kind]
    fn = make_fn(cfg, use_pallas=use_pallas)
    args = [
        jax.ShapeDtypeStruct(shape, _DTYPES[dt]) for _, dt, shape in in_specs(cfg)
    ]
    # keep_unused=True: the flat signature is a fixed ABI with the Rust
    # marshaler — params unused by an entrypoint (e.g. the logits layer in
    # `embed`) must still be accepted.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    return to_hlo_text(lowered)


def lower_smoke() -> str:
    """Tiny fn(x,y) = (x@y + 2,) artifact for fast runtime unit tests."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def _spec_json(specs: List[Spec]) -> List[dict]:
    return [
        {"name": n, "dtype": dt, "shape": list(shape)} for n, dt, shape in specs
    ]


def build_manifest_entry(cfg: ModelConfig, kind: str, fname: str) -> dict:
    _, in_specs, out_specs = ENTRYPOINT_SPECS[kind]
    return {
        "name": f"{cfg.name}_{kind}",
        "file": fname,
        "kind": kind,
        "model": cfg.model,
        "config": {
            "layers": cfg.layers,
            "feat": cfg.feat,
            "hidden": cfg.hidden,
            "classes": cfg.classes,
            "batch": cfg.batch,
            "fanout": cfg.fanout,
            "push_batch": cfg.push_batch,
            "param_count": cfg.param_count(),
        },
        "inputs": _spec_json(in_specs(cfg)),
        "outputs": _spec_json(out_specs(cfg)),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated entrypoint-name substrings to regenerate",
    )
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference path instead of the Pallas kernels",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = args.only.split(",") if args.only else None

    entries = []
    for cfg in DEFAULT_CONFIGS:
        # SAGE fanout sweep is not evaluated by the paper; skip non-default
        # fanouts for SAGE to bound compile time.
        kinds = ["train", "eval", "embed"]
        for kind in kinds:
            name = f"{cfg.name}_{kind}"
            fname = f"{name}.hlo.txt"
            entries.append(build_manifest_entry(cfg, kind, fname))
            if only and not any(s in name for s in only):
                continue
            text = lower_entrypoint(cfg, kind, use_pallas=not args.no_pallas)
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:12]
            print(f"wrote {path}  ({len(text)} chars, sha={digest})")

    smoke_name = "smoke.hlo.txt"
    with open(os.path.join(args.out, smoke_name), "w") as f:
        f.write(lower_smoke())
    print(f"wrote {os.path.join(args.out, smoke_name)}")

    manifest = {
        "version": 1,
        "generated_by": "python/compile/aot.py",
        "smoke": {
            "file": smoke_name,
            "inputs": _spec_json(
                [("x", "f32", (2, 2)), ("y", "f32", (2, 2))]
            ),
            "outputs": _spec_json([("out", "f32", (2, 2))]),
        },
        "entrypoints": entries,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(entries)} entrypoints)")


if __name__ == "__main__":
    main()
