"""Layer-1 Pallas kernels: fused masked-mean aggregation + linear transform.

This is the compute hot-spot of federated GNN training (every GNN layer,
forward *and* backward, is one of these ops). The paper runs it on NVIDIA
GPUs through DGL; here it is re-thought for a TPU-shaped memory hierarchy
(see DESIGN.md §Hardware-Adaptation):

* the gathered neighbour block ``[TILE_N, K, D]`` is staged HBM->VMEM by the
  ``BlockSpec`` grid (the analogue of the paper's per-threadblock shared-mem
  staging),
* the masked mean is a VPU reduction over the K axis,
* the transform is an MXU matmul ``(TILE_N, D) @ (D, H)``, which dominates
  FLOPs, so MXU utilization ~= matmul_flops / total_flops.

``interpret=True`` is mandatory on this CPU-only testbed: real TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute. The
interpret path lowers to plain HLO, so the kernel ships inside the same AOT
artifact the Rust coordinator loads.

Autodiff: ``pallas_call`` has no automatic VJP, so each fused layer is a
``jax.custom_vjp`` whose forward runs the Pallas kernel and whose backward
is the hand-derived analytic gradient (validated against ``jax.grad`` of
the jnp oracle in ``python/tests``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 keeps the VMEM working set of the worst-case block
# (TILE_N*K*D + D*H + TILE_N*H floats ~ 320 KiB at K=16, D=H=64) well under
# 16 MiB for the shapes we
# ship (K<=16, D,H<=64) while filling the 8x128 VPU lanes.
DEFAULT_TILE = 128


def _pick_tile(n: int) -> int:
    """Largest power-of-two tile <= DEFAULT_TILE that divides ``n``."""
    t = DEFAULT_TILE
    while t > 1 and n % t != 0:
        t //= 2
    return max(t, 1)


# ---------------------------------------------------------------------------
# GraphConv: out = act((self + masked_mean(neigh)) @ W + b)
# ---------------------------------------------------------------------------


def _gc_kernel(neigh_ref, self_ref, mask_ref, w_ref, b_ref, out_ref, *, activate):
    neigh = neigh_ref[...]  # [T, K, D]
    mask = mask_ref[...]  # [T, K]
    # Masked sum over the K axis, then clamp-1 mean: one pass over the block.
    s = jnp.einsum("tkd,tk->td", neigh, mask, preferred_element_type=jnp.float32)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    agg = self_ref[...] + s / cnt
    z = (
        jnp.dot(agg, w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )
    out_ref[...] = jnp.maximum(z, 0.0) if activate else z


def _gc_pallas(neigh, self_x, mask, w, b, activate: bool):
    n, k, d = neigh.shape
    h = w.shape[1]
    t = _pick_tile(n)
    grid = (n // t,)
    return pl.pallas_call(
        functools.partial(_gc_kernel, activate=activate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, d), lambda i: (i, 0)),
            pl.BlockSpec((t, k), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), jnp.float32),
        interpret=True,
    )(neigh, self_x, mask, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def fused_gc_layer(neigh, self_x, mask, w, b, activate: bool):
    """Fused GraphConv layer (Pallas forward, analytic backward).

    Args:
      neigh:  ``[N, K, D]`` gathered previous-layer embeddings of sampled
              neighbours (padding rows arbitrary — masked out).
      self_x: ``[N, D]`` previous-layer embeddings of the rows themselves.
      mask:   ``[N, K]`` 1.0 valid / 0.0 padded sample slots.
      w, b:   ``[D, H]``, ``[H]`` layer parameters.
      activate: static; apply ReLU (hidden layers) or not (logits layer).

    Returns:
      ``[N, H]`` layer output.
    """
    return _gc_pallas(neigh, self_x, mask, w, b, activate)


def _gc_fwd(neigh, self_x, mask, w, b, activate):
    out = _gc_pallas(neigh, self_x, mask, w, b, activate)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    agg = self_x + jnp.einsum("nkd,nk->nd", neigh, mask) / cnt
    return out, (mask, cnt, agg, out, w)


def _gc_bwd(activate, res, g_out):
    mask, cnt, agg, out, w = res
    g_z = g_out * (out > 0.0) if activate else g_out
    g_w = agg.T @ g_z
    g_b = jnp.sum(g_z, axis=0)
    g_agg = g_z @ w.T  # [N, D]
    g_mean = g_agg / cnt  # d(mean)/d(sum) = 1/cnt
    g_neigh = g_mean[:, None, :] * mask[:, :, None]  # [N, K, D]
    g_mask = jnp.zeros_like(mask)  # mask is non-differentiable data
    return g_neigh, g_agg, g_mask, g_w, g_b


fused_gc_layer.defvjp(_gc_fwd, _gc_bwd)


# ---------------------------------------------------------------------------
# SAGEConv: out = act(self @ Ws + masked_mean(neigh) @ Wn + b)
# ---------------------------------------------------------------------------


def _sage_kernel(
    neigh_ref, self_ref, mask_ref, ws_ref, wn_ref, b_ref, out_ref, *, activate
):
    neigh = neigh_ref[...]
    mask = mask_ref[...]
    s = jnp.einsum("tkd,tk->td", neigh, mask, preferred_element_type=jnp.float32)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    mean = s / cnt
    z = (
        jnp.dot(self_ref[...], ws_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(mean, wn_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )
    out_ref[...] = jnp.maximum(z, 0.0) if activate else z


def _sage_pallas(neigh, self_x, mask, w_self, w_neigh, b, activate: bool):
    n, k, d = neigh.shape
    h = w_self.shape[1]
    t = _pick_tile(n)
    grid = (n // t,)
    return pl.pallas_call(
        functools.partial(_sage_kernel, activate=activate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, d), lambda i: (i, 0)),
            pl.BlockSpec((t, k), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), jnp.float32),
        interpret=True,
    )(neigh, self_x, mask, w_self, w_neigh, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def fused_sage_layer(neigh, self_x, mask, w_self, w_neigh, b, activate: bool):
    """Fused SAGEConv (mean) layer. See :func:`fused_gc_layer` for shapes."""
    return _sage_pallas(neigh, self_x, mask, w_self, w_neigh, b, activate)


def _sage_fwd(neigh, self_x, mask, w_self, w_neigh, b, activate):
    out = _sage_pallas(neigh, self_x, mask, w_self, w_neigh, b, activate)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    mean = jnp.einsum("nkd,nk->nd", neigh, mask) / cnt
    return out, (mask, cnt, mean, self_x, out, w_self, w_neigh)


def _sage_bwd(activate, res, g_out):
    mask, cnt, mean, self_x, out, w_self, w_neigh = res
    g_z = g_out * (out > 0.0) if activate else g_out
    g_ws = self_x.T @ g_z
    g_wn = mean.T @ g_z
    g_b = jnp.sum(g_z, axis=0)
    g_self = g_z @ w_self.T
    g_mean = g_z @ w_neigh.T / cnt
    g_neigh = g_mean[:, None, :] * mask[:, :, None]
    g_mask = jnp.zeros_like(mask)
    return g_neigh, g_self, g_mask, g_ws, g_wn, g_b


fused_sage_layer.defvjp(_sage_fwd, _sage_bwd)
