"""Pure-jnp oracle for the fused GNN layer kernels.

These are the ground-truth semantics the Pallas kernels in
``agg_matmul.py`` must match (fp32, same op order where it matters). They
are also used as the ``use_pallas=False`` model path so the L2 graph can be
lowered with or without the L1 kernel for A/B comparison.

Semantics
---------
``masked_mean``: mean over the K sampled neighbours weighted by a {0,1}
validity mask; rows with zero valid neighbours aggregate to the zero vector
(denominator clamped to 1).

GraphConv (GCN-with-self-loop flavour, paper ref [15]):
    ``out = act((self + mean_neigh) @ W + b)``

SAGEConv (mean aggregator, paper ref [9]):
    ``out = act(self @ Ws + mean_neigh @ Wn + b)``
"""

from __future__ import annotations

import jax.numpy as jnp


def masked_mean(neigh: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Masked mean over axis 1.

    Args:
      neigh: ``[N, K, D]`` gathered neighbour embeddings.
      mask:  ``[N, K]`` 1.0 for valid sampled edges, 0.0 for padding.

    Returns:
      ``[N, D]`` per-row mean of the valid neighbours (zeros if none).
    """
    s = jnp.einsum("nkd,nk->nd", neigh, mask)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return s / cnt


def gc_layer(neigh, self_x, mask, w, b, activate: bool):
    """GraphConv layer: ``act((self + masked_mean(neigh)) @ W + b)``."""
    agg = self_x + masked_mean(neigh, mask)
    z = agg @ w + b[None, :]
    return jnp.maximum(z, 0.0) if activate else z


def sage_layer(neigh, self_x, mask, w_self, w_neigh, b, activate: bool):
    """SAGEConv layer: ``act(self @ Ws + masked_mean(neigh) @ Wn + b)``."""
    z = self_x @ w_self + masked_mean(neigh, mask) @ w_neigh + b[None, :]
    return jnp.maximum(z, 0.0) if activate else z
