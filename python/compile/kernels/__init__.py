"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .agg_matmul import fused_gc_layer, fused_sage_layer  # noqa: F401
from . import ref  # noqa: F401
